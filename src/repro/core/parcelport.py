"""The HPX parcelport abstraction (paper §2.3, Listing 2) and localities.

The contract a parcelport implements::

    send(locality, parcel, cb) -> None        # any worker thread may call
    background_work() -> bool                 # workers call when idle

and the upper layer provides::

    allocate_zc_chunks(nzc_chunk) -> buffers  # receiver-side zc allocation
    handle_parcel(parcel) -> None             # deliver to the runtime

Also implements HPX **parcel aggregation** (paper §2.2.2): one parcel queue
per destination; a send enqueues then drains-and-merges everything pending
for that destination into a single aggregate parcel.

Aggregation can be **threshold-aware** (``agg_limit_bytes``): instead of
merging the whole queue into one arbitrarily large aggregate — which silently
pushes a pile of eager-sized parcels over the protocol engine's
``eager_threshold`` and onto the rendezvous path — the drain packs parcels
greedily (FIFO order) into aggregates whose projected serialized size stays
within the limit.  With the limit set to the eager threshold, every
aggregate built from eager-sized parcels still ships as ONE eager message
(it fills at most one bounce buffer); a single parcel already over the limit
forms its own batch and takes the rendezvous path it would have taken
anyway.  ``agg_limit_bytes=0`` keeps the classic unbounded merge.
"""
from __future__ import annotations

import itertools
import struct
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

from .fabric import Fabric
from .parcel import (
    Chunk,
    Parcel,
    SendCallback,
    deserialize_action,
    serialize_action,
    zc_sizes_from_nzc,
)

__all__ = [
    "Parcelport",
    "Locality",
    "World",
    "aggregate_parcels",
    "aggregate_projected_bytes",
    "split_aggregate",
]

AGG_MAGIC = 0xA6

# Parcel-id bit layout: bits 0..39 are the per-locality counter, bits 40..47
# the source rank (Locality seeds its counter at ``rank << 40``), and bits
# 48..63 are RESERVED for aggregate sub-ids: parcel ``i`` of a split
# aggregate gets ``base_id | ((i + 1) << AGG_SUB_SHIFT)``.  Ordinary ids
# never touch the reserved range, so sub-ids cannot collide with dense
# neighbouring ids (the old ``base_id * 1000 + i`` scheme collided as soon
# as ids were dense or an aggregate held >= 1000 parcels).
AGG_SUB_SHIFT = 48
AGG_MAX_PARCELS = (1 << 16) - 1

# Serialized-aggregate framing overhead: the <BI> preamble plus one <II>
# record per member parcel (see aggregate_parcels).  aggregate_projected_bytes
# must stay in lockstep with the actual encoder.
AGG_PREAMBLE_BYTES = 5
AGG_PER_PARCEL_BYTES = 8


def aggregate_projected_bytes(parcels: Sequence[Parcel]) -> int:
    """``total_bytes`` the aggregate of ``parcels`` will have, without
    building it — the threshold-aware drain sizes batches with this."""
    return AGG_PREAMBLE_BYTES + sum(AGG_PER_PARCEL_BYTES + p.total_bytes for p in parcels)


def aggregate_parcels(parcels: Sequence[Parcel]) -> Parcel:
    """Merge parcels sharing a destination into one (paper §2.2.2)."""
    assert parcels, "cannot aggregate zero parcels"
    assert len(parcels) <= AGG_MAX_PARCELS, "aggregate exceeds the sub-id bit range"
    first = parcels[0]
    parts = [struct.pack("<BI", AGG_MAGIC, len(parcels))]
    zc: List[Chunk] = []
    for p in parcels:
        parts.append(struct.pack("<II", p.nzc_chunk.size, len(p.zc_chunks)))
        parts.append(p.nzc_chunk.data)
        zc.extend(p.zc_chunks)
    return Parcel(
        parcel_id=first.parcel_id,
        source=first.source,
        dest=first.dest,
        nzc_chunk=Chunk(b"".join(parts)),
        zc_chunks=zc,
    )


def is_aggregate(parcel: Parcel) -> bool:
    return parcel.nzc_chunk.size >= 5 and parcel.nzc_chunk.data[0] == AGG_MAGIC


def split_aggregate(parcel: Parcel) -> List[Parcel]:
    buf = parcel.nzc_chunk.data
    (_, n) = struct.unpack_from("<BI", buf, 0)
    off = 5
    zc_off = 0
    out: List[Parcel] = []
    for i in range(n):
        nzc_size, n_zc = struct.unpack_from("<II", buf, off)
        off += 8
        nzc = buf[off : off + nzc_size]
        off += nzc_size
        chunks = parcel.zc_chunks[zc_off : zc_off + n_zc]
        zc_off += n_zc
        out.append(
            Parcel(
                parcel_id=parcel.parcel_id | ((i + 1) << AGG_SUB_SHIFT),
                source=parcel.source,
                dest=parcel.dest,
                nzc_chunk=Chunk(bytes(nzc)),
                zc_chunks=list(chunks),
            )
        )
    return out


class Parcelport:
    """Abstract parcelport (one per communication library per locality)."""

    def __init__(self, locality: "Locality", aggregation: bool = False, agg_limit_bytes: int = 0):
        self.locality = locality
        self.aggregation = aggregation
        # Threshold-aware aggregation: max projected aggregate size per
        # batch (0 = classic unbounded merge).
        self.agg_limit_bytes = agg_limit_bytes
        self._agg_queues: Dict[int, deque] = {}
        self._agg_lock = threading.Lock()
        self.stats_sent = 0
        self.stats_received = 0
        self.stats_agg_batches = 0  # threshold-aware drains that split

    # -- public API (Listing 2) ---------------------------------------------
    def send(self, dest: int, parcel: Parcel, cb: Optional[SendCallback] = None) -> None:
        if not self.aggregation:
            self._send_impl(dest, parcel, cb)
            return
        # Aggregation path: enqueue, then drain everything for this dest.
        with self._agg_lock:
            q = self._agg_queues.setdefault(dest, deque())
            q.append((parcel, cb))
            drained = list(q)
            q.clear()
        if not drained:
            return
        batches = self._agg_batches(drained)
        if len(batches) > 1:
            self.stats_agg_batches += len(batches)
        for batch in batches:
            self._send_batch(dest, batch)

    def _agg_batches(self, drained: List[tuple]) -> List[List[tuple]]:
        """Split the drained queue into aggregate batches.

        Unbounded mode returns one batch (everything merges).  With
        ``agg_limit_bytes`` set, parcels pack greedily in FIFO order until
        the projected aggregate size (:func:`aggregate_projected_bytes`)
        would exceed the limit — so an aggregate of eager-sized parcels
        never spills past the eager threshold into rendezvous.  A parcel
        that alone exceeds the limit gets its own batch (it is rendezvous
        traffic regardless)."""
        if self.agg_limit_bytes <= 0:
            return [drained]
        batches: List[List[tuple]] = []
        cur: List[tuple] = []
        cur_bytes = AGG_PREAMBLE_BYTES
        for p, cb in drained:
            need = AGG_PER_PARCEL_BYTES + p.total_bytes
            if cur and cur_bytes + need > self.agg_limit_bytes:
                batches.append(cur)
                cur, cur_bytes = [], AGG_PREAMBLE_BYTES
            cur.append((p, cb))
            cur_bytes += need
        if cur:
            batches.append(cur)
        return batches

    def _send_batch(self, dest: int, batch: List[tuple]) -> None:
        if len(batch) == 1:
            self._send_impl(dest, batch[0][0], batch[0][1])
            return
        cbs = [c for (_p, c) in batch if c is not None]
        agg = aggregate_parcels([p for (p, _c) in batch])

        def agg_cb(_parcel: Parcel) -> None:
            for c in cbs:
                c(_parcel)

        self._send_impl(dest, agg, agg_cb)

    def background_work(self) -> bool:
        raise NotImplementedError

    def pending_work(self) -> bool:
        """True while the parcelport still holds work no completion will
        ever surface on its own (e.g. backpressured posts parked for
        retry).  ``World.drain`` refuses to call a world quiescent while
        any parcelport reports pending work."""
        return False

    # -- subclass hook --------------------------------------------------------
    def _send_impl(self, dest: int, parcel: Parcel, cb: Optional[SendCallback]) -> None:
        raise NotImplementedError

    # -- receiver-side glue ---------------------------------------------------
    def deliver(self, parcel: Parcel) -> None:
        self.stats_received += 1
        if is_aggregate(parcel):
            for p in split_aggregate(parcel):
                self.locality.handle_parcel(p)
        else:
            self.locality.handle_parcel(parcel)


class Locality:
    """One HPX process: action registry + the upper-layer callbacks."""

    def __init__(self, rank: int, world: "World"):
        self.rank = rank
        self.world = world
        self.actions: Dict[str, Callable[..., Any]] = {}
        self.parcelport: Optional[Parcelport] = None
        # Locality-unique parcel ids, also used as follow-up message tags.
        # Start at 1: tag 0 is reserved for header messages (TAG_HEADER).
        self._pid = itertools.count((rank << 40) + 1)
        self.handled = itertools.count()
        self.handled_count = 0

    def register_action(self, name: str, fn: Callable[..., Any]) -> None:
        self.actions[name] = fn

    # upper-layer callbacks (Listing 2) --------------------------------------
    def allocate_zc_chunks(self, nzc_data: bytes) -> List[bytearray]:
        """Allocate receive buffers for zero-copy chunks; the nzc chunk
        carries their sizes."""
        return [bytearray(sz) for sz in zc_sizes_from_nzc(nzc_data)]

    def handle_parcel(self, parcel: Parcel) -> None:
        action, args = deserialize_action(parcel)
        self.handled_count += 1
        fn = self.actions.get(action)
        if fn is None:
            raise KeyError(f"locality {self.rank}: unknown action {action!r}")
        fn(*args)

    # convenience: HPX async(locality, action, args...) ----------------------
    def async_action(
        self,
        dest: int,
        action: str,
        *args: Any,
        cb: Optional[SendCallback] = None,
        zero_copy_threshold: Optional[int] = None,
    ) -> None:
        kw = {}
        if zero_copy_threshold is not None:
            kw["zero_copy_threshold"] = zero_copy_threshold
        parcel = serialize_action(next(self._pid), self.rank, dest, action, args, **kw)
        assert self.parcelport is not None, "parcelport not attached"
        self.parcelport.send(dest, parcel, cb)


class World:
    """A set of in-process localities joined by one fabric."""

    def __init__(
        self,
        n_localities: int,
        parcelport_factory: Callable[["Locality", Fabric], Parcelport],
        devices_per_rank: int = 1,
        fabric_kwargs: Optional[Dict[str, Any]] = None,
    ):
        self.fabric = Fabric(n_localities, devices_per_rank=devices_per_rank, **(fabric_kwargs or {}))
        self.localities = [Locality(r, self) for r in range(n_localities)]
        for loc in self.localities:
            loc.parcelport = parcelport_factory(loc, self.fabric)

    def progress_all(self, rounds: int = 1) -> bool:
        """Drive every locality's background work (single-threaded pump,
        used by tests; the executor drives this from worker threads)."""
        any_progress = False
        for _ in range(rounds):
            for loc in self.localities:
                if loc.parcelport.background_work():
                    any_progress = True
        return any_progress

    def drain(self, max_rounds: int = 100_000) -> None:
        """Pump until quiescent (no progress for a few consecutive rounds).
        Raises if the world stops moving while a parcelport still holds
        parked (backpressured) posts — that is silent message loss, not
        quiescence."""
        idle = 0
        for _ in range(max_rounds):
            if self.progress_all():
                idle = 0
            else:
                idle += 1
                if idle > 8:
                    if any(loc.parcelport.pending_work() for loc in self.localities):
                        raise RuntimeError(
                            "world stalled with backpressured posts still parked "
                            "(undeliverable send: check bounce-buffer sizing / send-queue depth)"
                        )
                    return
        raise RuntimeError("world did not quiesce")
