"""Completion mechanisms (paper §3.3.2, §5.2).

The paper studies four ways a communication runtime can hand completed
operations back to its client:

* :class:`LCRQueue` — an LCRQ-style FAA-based MPMC array queue (Morrison &
  Afek, PPoPP'13), LCI's default completion queue.  The real LCRQ relies on
  x86 ``FAA``/``CAS2``; here we implement the same *structure* (a linked list
  of fixed-size ring segments, enqueue/dequeue via fetch-and-add tickets)
  with CPython primitives.  CPython's GIL makes each bytecode atomic enough
  for ``itertools.count`` to serve as a true fetch-and-add, which preserves
  the algorithm's lock-freedom property at the Python level.
* :class:`MichaelScottQueue` — the classic CAS-based linked-list MPMC queue
  (the paper's ``queue_ms`` variant).
* :class:`LockQueue` — a deque behind a mutex (the ``queue_lock`` variant).
* :class:`Synchronizer` — a single-slot completion object, equivalent to an
  MPI request (the ``*_sync`` variants); :class:`SynchronizerPool` mirrors
  the MPI parcelport's shared request pools.

All queues implement ``push(item)`` / ``pop() -> item | None`` (non-blocking)
and report ``cost_model_name`` so the amtsim layer can attach calibrated
costs to the same structures.

Every class here also conforms to the unified
:class:`repro.core.comm.interface.CompletionTarget` surface —
``signal(item)`` / ``reap() -> item | None`` — so a communication backend
hands completions to *any* of them through one call, and the parcelports
collect them the same way regardless of which mechanism a variant selects
(queue vs synchronizer vs pool is a calibrated-cost question, not an
interface question).
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from typing import Any, List, Optional, Tuple

from ..analysis.sanitizer import note_exercise

__all__ = [
    "CompletionQueue",
    "LCRQueue",
    "MichaelScottQueue",
    "LockQueue",
    "Synchronizer",
    "SynchronizerPool",
    "make_completion_queue",
]


class CompletionQueue:
    """Interface: multi-producer multi-consumer completion queue."""

    cost_model_name = "abstract"

    def push(self, item: Any) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def pop(self) -> Optional[Any]:  # pragma: no cover - interface
        raise NotImplementedError

    def drain(self, max_n: int = 16) -> List[Any]:
        """Pop up to ``max_n`` items (stops at the first empty poll) — the
        parcelport's completion-dispatch batch."""
        out: List[Any] = []
        for _ in range(max_n):
            item = self.pop()
            if item is None:
                break
            out.append(item)
        return out

    # -- unified CompletionTarget surface (repro.core.comm.interface) -------
    def signal(self, item: Any) -> None:
        """Producer side of :class:`~repro.core.comm.interface.
        CompletionTarget`: for a queue, signalling is enqueuing."""
        self.push(item)

    def reap(self) -> Optional[Any]:
        """Consumer side: one completed item, or ``None``."""
        return self.pop()

    def __len__(self) -> int:  # pragma: no cover - interface
        raise NotImplementedError


_TAKEN = object()  # tombstone: a dequeuer claimed this slot before any enqueuer


class _CRQSegment:
    """One fixed-size ring of an LCRQ: slots claimed by FAA tickets.

    ``slots`` is a dict so we can use ``dict.setdefault`` — a single C-level
    operation, hence atomic under the GIL — as the slot-resolution CAS:
    every ticket resolves exactly once, either enqueuer-first (item stored;
    the dequeuer with that ticket returns it) or dequeuer-first (tombstone
    stored; the enqueuer observes it and retries with a fresh ticket).  This
    is the same safe/unsafe-slot protocol as the real CRQ.
    """

    __slots__ = ("slots", "head", "tail", "next", "size")

    def __init__(self, size: int):
        self.size = size
        self.slots: dict = {}
        self.head = itertools.count()  # dequeue ticket source (FAA)
        self.tail = itertools.count()  # enqueue ticket source (FAA)
        self.next: Optional["_CRQSegment"] = None


class LCRQueue(CompletionQueue):
    """FAA-based MPMC queue structured like LCRQ (Morrison & Afek).

    Enqueue/dequeue each take a ticket via fetch-and-add; when a segment's
    tickets are exhausted a new segment is linked (the "CRQ of rings"
    construction; the link lock is amortized over ``segment_size`` ops,
    standing in for the CAS on the ring list).  Lossless and duplicate-free
    under arbitrary thread interleavings — see :class:`_CRQSegment`.
    """

    cost_model_name = "lcrq"
    _BURN_BUDGET = 4  # empty-slot tombstones one pop() may place

    def __init__(self, segment_size: int = 1024):
        self._segment_size = segment_size
        seg = _CRQSegment(segment_size)
        self._head_seg = seg
        self._tail_seg = seg
        self._link_lock = threading.Lock()  # only for linking new segments
        self._pushed = 0  # stats only (racy increments are acceptable)
        self._popped = 0

    def push(self, item: Any) -> None:
        if item is None:
            raise ValueError("None is reserved for 'queue empty'")
        # deliberately lock-free: the sanitizer counts traffic here but
        # does not lockset-check it (correctness is the FAA protocol)
        note_exercise("LCRQueue", id(self))
        while True:
            seg = self._tail_seg
            t = next(seg.tail)
            if t < seg.size:
                if seg.slots.setdefault(t, item) is item:
                    self._pushed += 1
                    return
                continue  # slot tombstoned by an overtaking dequeuer: retry
            # Segment exhausted: link a fresh one.
            with self._link_lock:
                if self._tail_seg is seg:
                    new_seg = _CRQSegment(self._segment_size)
                    seg.next = new_seg
                    self._tail_seg = new_seg

    def pop(self) -> Optional[Any]:
        note_exercise("LCRQueue", id(self))
        burns = 0
        while True:
            seg = self._head_seg
            h = next(seg.head)
            if h < seg.size:
                item = seg.slots.get(h)
                if item is None:
                    # Our ticket beat any enqueuer.  Spin briefly (an
                    # in-flight push may land), then tombstone and give up
                    # after a small budget — the caller polls in a loop.
                    for _ in range(32):
                        item = seg.slots.get(h)
                        if item is not None:
                            break
                    if item is None:
                        item = seg.slots.setdefault(h, _TAKEN)
                        if item is _TAKEN:
                            burns += 1
                            if burns >= self._BURN_BUDGET:
                                return None
                            continue
                if item is _TAKEN:
                    continue  # tombstone from another dequeuer: skip
                self._popped += 1
                return item
            nxt = seg.next
            if nxt is None:
                return None
            with self._link_lock:
                if self._head_seg is seg and seg.next is not None:
                    self._head_seg = seg.next

    def __len__(self) -> int:
        return max(0, self._pushed - self._popped)


class _MSNode:
    __slots__ = ("value", "next")

    def __init__(self, value: Any):
        self.value = value
        self.next: Optional["_MSNode"] = None


class MichaelScottQueue(CompletionQueue):
    """CAS-based linked-list MPMC queue (Michael & Scott, PODC'96).

    CPython has no CAS; we emulate the per-pointer CAS with a tiny lock per
    operation, which preserves the algorithm's *structure* (separate
    head/tail contention points) — the amtsim cost model is what carries the
    performance distinction vs LCRQ (paper Fig 7: MS is not enough to reach
    peak message rate).
    """

    cost_model_name = "ms"

    def __init__(self):
        dummy = _MSNode(None)
        self._head = dummy
        self._tail = dummy
        self._head_lock = threading.Lock()
        self._tail_lock = threading.Lock()

    def push(self, item: Any) -> None:
        node = _MSNode(item)
        with self._tail_lock:
            self._tail.next = node
            self._tail = node

    def pop(self) -> Optional[Any]:
        with self._head_lock:
            nxt = self._head.next
            if nxt is None:
                return None
            self._head = nxt
            value = nxt.value
            nxt.value = None
            return value

    def __len__(self) -> int:
        n = 0
        node = self._head.next
        while node is not None:
            n += 1
            node = node.next
        return n


class LockQueue(CompletionQueue):
    """Single coarse lock around a deque (the ``queue_lock`` variant)."""

    cost_model_name = "lock"

    def __init__(self):
        self._q: deque = deque()
        self._lock = threading.Lock()

    def push(self, item: Any) -> None:
        with self._lock:
            self._q.append(item)

    def pop(self) -> Optional[Any]:
        with self._lock:
            if not self._q:
                return None
            return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)


class Synchronizer:
    """Single-slot completion object ≈ an MPI request (paper §5.1).

    "We specialize the completion queue to the case where it will never
    contain more than one entry."
    """

    cost_model_name = "sync"
    __slots__ = ("_item", "_signaled")

    def __init__(self):
        self._item: Any = None
        self._signaled = False

    def signal(self, item: Any = True) -> None:
        self._item = item
        self._signaled = True  # single GIL-atomic store = the 4B signal write

    def test(self) -> Optional[Any]:
        """Non-blocking test; returns the item once, like MPI_Test."""
        if self._signaled:
            self._signaled = False
            item = self._item
            self._item = None
            return item
        return None

    def reap(self) -> Optional[Any]:
        """Unified CompletionTarget surface: reaping a synchronizer is one
        nonblocking test."""
        return self.test()

    @property
    def ready(self) -> bool:
        return self._signaled


class SynchronizerPool:
    """Shared pool of pending synchronizers, polled round-robin one per call
    under a try-lock — the exact structure of the MPI parcelport's request
    pools (paper §3.3.2: C++ deque + HPX try-lock, one ``MPI_Test`` per
    ``background_work``)."""

    cost_model_name = "sync_pool"

    def __init__(self):
        self._pool: deque = deque()
        self._lock = threading.Lock()

    def add(self, sync: Synchronizer, payload: Any = None) -> None:
        with self._lock:
            self._pool.append((sync, payload))

    def poll_one(self) -> Optional[Tuple[Any, Any]]:
        """Try-lock; test one request round-robin.  Returns ``(payload,
        completion_item)`` for a completed request, else None (nothing
        ready, nothing pending, or lock not acquired)."""
        if not self._lock.acquire(blocking=False):
            return None
        try:
            if not self._pool:
                return None
            sync, payload = self._pool.popleft()
            item = sync.test()
            if item is None:
                self._pool.append((sync, payload))  # re-queue, round robin
                return None
            return (payload, item)
        finally:
            self._lock.release()

    def reap(self) -> Optional[Tuple[Any, Any]]:
        """Unified CompletionTarget surface: one round-robin poll.  (The
        pool is a *poller over* synchronizers, so it has no ``signal`` —
        producers signal the member synchronizer directly.)"""
        return self.poll_one()

    def __len__(self) -> int:
        return len(self._pool)


def make_completion_queue(kind: str) -> CompletionQueue:
    """Factory used by parcelport variants (paper Fig 7)."""
    if kind == "lcrq":
        return LCRQueue()
    if kind == "ms":
        return MichaelScottQueue()
    if kind == "lock":
        return LockQueue()
    raise ValueError(f"unknown completion queue kind: {kind}")
