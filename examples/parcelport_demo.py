"""The paper's factor study, live: run the same workload over parcelport
variants and watch the four communication needs show up as throughput.

Run:  PYTHONPATH=src python examples/parcelport_demo.py
"""
import time

from repro.amtsim.workloads import flood, octotiger

LADDER = [
    ("mpi", "MPI parcelport: big lock, request pool, implicit progress"),
    ("block", "LCI mimicking MPI: coarse blocking lock"),
    ("try", "…replace blocking lock with try lock"),
    ("try_progress", "…add explicit frequent progress"),
    ("block_d2", "…or instead replicate devices (2)"),
    ("lci", "full LCI: lock-free + queues + put + explicit progress"),
]


def main() -> int:
    print("paper §5.3 ladder — 8 B message rate (64 threads) and Octo-Tiger time\n")
    base_app = None
    for variant, desc in LADDER:
        t0 = time.time()
        rate = flood(variant, msg_size=8, nthreads=64, nmsgs=3000).rate
        app = octotiger(variant, n_nodes=8, workers=8, total_subgrids=512, timesteps=3).elapsed
        base_app = base_app or app
        print(
            f"{variant:13s} {rate/1e6:6.2f} M msg/s   octotiger {app*1e3:7.2f} ms "
            f"({base_app/app:4.2f}x vs mpi)   [{desc}]"
        )
    print("\nobservation: each technique addresses thread contention somewhere —")
    print("the paper's conclusion is that contention is the crucial factor.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
