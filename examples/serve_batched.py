"""Continuous-batching serving demo: multithreaded clients, slot scheduler,
greedy decode — the serving-side end-to-end driver.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch zamba2-1.2b]
"""
import argparse
import threading
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import InferenceServer, ServeConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args()

    arch = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), arch)
    server = InferenceServer(arch, params, ServeConfig(slots=4, context=128))
    rng = np.random.default_rng(0)
    reqs = []

    def client(i):
        prompt = rng.integers(0, arch.vocab_size, size=8 + i % 5).tolist()
        reqs.append(server.submit(prompt, max_new=args.max_new))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(args.requests)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    server.run_until_idle()
    dt = time.monotonic() - t0
    for r in reqs[:3]:
        print(f"req {r.rid}: {len(r.out_tokens)} tokens → {r.out_tokens[:8]}…")
    print(
        f"\n{len(reqs)} requests / {server.steps} engine steps / "
        f"{server.tokens_out} tokens in {dt:.1f}s ({server.tokens_out/dt:.1f} tok/s); "
        f"batched decode slots shared by all requests (continuous batching)"
    )
    return 0 if all(r.done_event.is_set() for r in reqs) else 1


if __name__ == "__main__":
    raise SystemExit(main())
