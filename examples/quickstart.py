"""Quickstart: the three layers of this repo in one script.

1. The paper's runtime — parcels over the LCI parcelport (core);
2. the quantitative model — one paper microbenchmark (amtsim);
3. the framework — a model forward/train step on any assigned arch.

Run:  PYTHONPATH=src python examples/quickstart.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.amtsim.workloads import flood
from repro.configs import get_smoke_config, list_archs
from repro.core.parcelport import World
from repro.core.variants import make_parcelport_factory
from repro.models import forward_train, init_params


def demo_parcelport() -> None:
    print("== 1. HPX parcelport abstraction over the LCI runtime ==")
    world = World(2, make_parcelport_factory("lci"), devices_per_rank=2)
    got = []
    world.localities[1].register_action("hello", lambda msg: got.append(msg))
    # async(locality, action, args...) — the HPX application interface
    world.localities[0].async_action(1, "hello", b"one-sided dynamic put \xf0\x9f\x9b\xb0")
    world.localities[0].async_action(1, "hello", b"Z" * 100_000)  # zero-copy path
    world.drain()
    print(f"   delivered {len(got)} parcels; sizes = {[len(g) for g in got]}")


def demo_simulator() -> None:
    print("== 2. Calibrated DES model: paper Fig 3a (message rate, 8 B) ==")
    for variant in ("mpi", "mpi_a", "lci"):
        r = flood(variant, msg_size=8, nthreads=32, nmsgs=2000)
        print(f"   {variant:6s}: {r.rate/1e6:6.2f} M msg/s")


def demo_framework(arch_name: str) -> None:
    print(f"== 3. Framework: {arch_name} (smoke config) forward pass ==")
    cfg = get_smoke_config(arch_name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(rng, (2, 32), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["prefix"] = jax.random.normal(rng, (2, cfg.n_prefix_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(rng, (2, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    logits, aux = forward_train(params, cfg, batch)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"   params={n_params/1e6:.1f}M logits={logits.shape} aux_loss={float(aux):.3f}")
    print(f"   (assigned archs: {', '.join(list_archs())})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()
    demo_parcelport()
    demo_simulator()
    demo_framework(args.arch)
