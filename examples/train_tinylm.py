"""End-to-end driver: train a ~100M LM for a few hundred steps (deliverable b).

Uses the full production path — executor-prefetched data pipeline, jitted
train step (microbatching + remat), async sharded checkpoints with restart,
straggler watchdog.  The model is a ~100M-param member of the tinyllama
family (same architecture, reduced depth/width so CPU finishes in minutes).

Run:  PYTHONPATH=src python examples/train_tinylm.py [--steps 300]
"""
import argparse
import time

from repro.configs import get_config
from repro.optim import OptHParams
from repro.train import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tinylm_ckpt")
    args = ap.parse_args()

    # ~100M params: tinyllama family, 8 layers × 640 wide
    arch = get_config("tinyllama-1.1b").variant(
        name="tinylm-100m", n_layers=8, d_model=640, n_heads=10, n_kv_heads=2,
        d_ff=1792, vocab_size=32000,
    )
    n = arch.param_count()
    print(f"model: {arch.name} — {n/1e6:.0f}M params, {arch.n_layers}L×{arch.d_model}")

    hp = OptHParams(lr_peak=3e-3, warmup_steps=30, total_steps=args.steps, weight_decay=0.01)
    tcfg = TrainConfig(microbatches=2, remat="dots")
    run = TrainerConfig(
        batch=args.batch, seq=args.seq, steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20,
    )
    t0 = time.time()
    trainer = Trainer(arch, hp, tcfg, run)
    summary = trainer.train()
    dt = time.time() - t0
    toks = args.batch * args.seq * summary["steps"]
    print(
        f"\ndone in {dt:.0f}s: loss {trainer.metrics_log[0]['loss']:.3f} → "
        f"{summary['final_loss']:.3f} over {summary['steps']} steps "
        f"({toks/dt/1e3:.1f}k tok/s); stragglers flagged: {summary['stragglers']}"
    )
    assert summary["final_loss"] < trainer.metrics_log[0]["loss"], "training must reduce loss"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
