"""Paper Fig 5 / §4.2.3: Expanse (IB) vs Delta (Slingshot-11 libfabric CQ lock)."""
from __future__ import annotations

import sys

from repro.amtsim.costs import DELTA, EXPANSE
from repro.amtsim.workloads import flood, octotiger

from .common import Claim, save_result, table


def run(fast: bool = False) -> dict:
    nthreads = 32 if fast else 64
    rows = []
    data: dict = {}
    for plat in (EXPANSE, DELTA):
        rate = flood("lci", msg_size=8, nthreads=nthreads, nmsgs=4000, platform=plat).rate
        app = octotiger("lci", n_nodes=8, workers=8, total_subgrids=512, timesteps=3,
                        platform=plat).elapsed
        mpi_app = octotiger("mpi", n_nodes=8, workers=8, total_subgrids=512, timesteps=3,
                            platform=plat).elapsed
        data[plat.name] = {"rate": rate, "octotiger": app, "octotiger_mpi": mpi_app}
        rows.append({"platform": plat.name, "rate": f"{rate/1e6:.2f}M/s",
                     "octotiger": f"{app*1e3:.2f}ms",
                     "lci_vs_mpi": f"{mpi_app/app:.2f}x"})
    claims = [
        Claim("Fig5", "Delta peak rate below Expanse (paper ~30% lower)", 1.05,
              data["expanse"]["rate"] / data["delta"]["rate"]),
        Claim("§4.2.3", "lci still beats mpi on Slingshot-11 (paper 1.2-3x)", 1.2,
              data["delta"]["octotiger_mpi"] / data["delta"]["octotiger"]),
    ]
    print(table(rows, ["platform", "rate", "octotiger", "lci_vs_mpi"], "Fig 5 IB vs Slingshot-11"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"data": data, "claims": [c.row() for c in claims]}
    save_result("slingshot", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
