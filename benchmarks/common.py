"""Shared benchmark helpers: result tables + paper-target validation."""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

OUT_DIR = Path(os.environ.get("REPRO_BENCH_OUT", "experiments/bench"))


def save_result(name: str, payload: dict) -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    payload = dict(payload)
    payload["benchmark"] = name
    payload["unix_time"] = time.time()
    (OUT_DIR / f"{name}.json").write_text(json.dumps(payload, indent=1))


def table(rows: List[dict], cols: List[str], title: str = "") -> str:
    out = [f"== {title} ==" if title else ""]
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(out)


@dataclass
class Claim:
    """A paper claim validated by a benchmark (EXPERIMENTS.md ledger)."""

    figure: str
    claim: str
    target: float
    achieved: float
    direction: str = ">="  # achieved vs target comparator for 'ok'

    @property
    def ok(self) -> bool:
        if self.direction == ">=":
            return self.achieved >= self.target
        if self.direction == "ordering":
            return self.achieved > 0
        return self.achieved <= self.target

    def row(self) -> dict:
        return {
            "figure": self.figure,
            "claim": self.claim,
            "paper": self.target,
            "achieved": round(self.achieved, 2),
            "status": "REPRODUCED" if self.ok else "PARTIAL",
        }
