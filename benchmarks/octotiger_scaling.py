"""Paper Fig 4: Octo-Tiger strong scaling (lci vs mpi vs mpi_a), plus a
resource-limit sweep over the ``lci_b{depth}`` bounded-injection family
(§3.3.4 / ROADMAP follow-up): the same application profile run with the
send ring and bounce pool bounded at each depth, with the backpressure and
occupancy counters recorded in the JSON artifact.  A second sweep varies
``limits.recv_slots`` alongside ``lci_b{depth}`` to contrast send-bound vs
**receive-bound** regimes (§3.1): scarce posted receives raise RNR events
but — retransmission, not loss — every task still completes."""
from __future__ import annotations

import sys
from dataclasses import replace

from repro.amtsim.parcelport_sim import sim_config_for_variant
from repro.amtsim.workloads import octotiger

from .common import Claim, save_result, table

NODES = (2, 8, 32, 128)
# The bounded-injection sweep (parameterized family, resolved on demand):
# ample -> scarce, against the unbounded control.
RESOURCE_SWEEP = ("lci", "lci_b64", "lci_b16", "lci_b4")
# Receive-bound regime: posted-receive depth swept on top of lci_b16
# (0 = unbounded control, ample, scarce).
RECV_SWEEP = (0, 64, 4)


def run(fast: bool = False) -> dict:
    nodes = (2, 8, 32) if fast else NODES
    subgrids = 2048 if not fast else 512
    workers = 16 if not fast else 8
    rows = []
    data: dict = {}
    for v in ("lci", "mpi", "mpi_a"):
        e = {}
        for n in nodes:
            r = octotiger(v, n_nodes=n, workers=workers, total_subgrids=subgrids,
                          timesteps=3, max_seconds=120.0)
            e[n] = r.elapsed
        data[v] = e
        rows.append({"variant": v, **{f"n{n}": f"{e[n]*1e3:.2f}ms" for n in nodes}})
    nmax = nodes[-1]
    speedup_small = data["mpi"][nodes[0]] / data["lci"][nodes[0]]
    speedup_large = data["mpi"][nmax] / data["lci"][nmax]
    claims = [
        Claim("Fig4", "lci/mpi speedup at max nodes (paper up to 2x)", 1.3, speedup_large),
        Claim("Fig4", "speedup grows with node count", 1.0, speedup_large / speedup_small),
    ]
    print(table(rows, ["variant"] + [f"n{n}" for n in nodes], "Fig 4 Octo-Tiger strong scaling"))

    # -- resource-limit sweep (lci_b{depth} family, §3.3.4) ------------------
    sweep_nodes = 8
    sweep_rows = []
    sweep: dict = {}
    for v in RESOURCE_SWEEP:
        r = octotiger(v, n_nodes=sweep_nodes, workers=workers,
                      total_subgrids=subgrids, timesteps=3, max_seconds=120.0)
        sweep[v] = {
            "elapsed": r.elapsed,
            "tasks": r.tasks,
            "backpressure_events": r.backpressure_events,
            "rnr_events": r.rnr_events,
            "send_queue_hw": r.send_queue_hw,
            "bounce_in_use_hw": r.bounce_in_use_hw,
            "retry_queue_hw": r.retry_queue_hw,
        }
        sweep_rows.append({
            "variant": v,
            "elapsed": f"{r.elapsed*1e3:.2f}ms",
            "backpressure": r.backpressure_events,
            "ring_hw": r.send_queue_hw,
            "bounce_hw": r.bounce_in_use_hw,
            "retry_hw": r.retry_queue_hw,
        })
    tasks_expected = sweep["lci"]["tasks"]
    b4, b64 = sweep["lci_b4"], sweep["lci_b64"]
    claims += [
        # ample resources are free: a 64-deep ring matches the unbounded run
        Claim("§3.3.4", "ample limits (lci_b64) within ~5% of unbounded lci",
              0.95, sweep["lci"]["elapsed"] / b64["elapsed"]),
        # scarce resources throttle but never lose work: backpressure fires
        # AND every task still completes
        Claim("§3.3.4", "scarce limits (lci_b4) backpressure, all tasks done",
              1.0, float(b4["backpressure_events"] if b4["tasks"] == tasks_expected else 0),
              direction="ordering"),
        # the ring occupancy high-water respects the configured depth
        Claim("§3.3.4", "lci_b4 send-ring occupancy bounded by depth 4",
              4.0, float(b4["send_queue_hw"]), direction="<="),
    ]
    print(table(sweep_rows, ["variant", "elapsed", "backpressure", "ring_hw", "bounce_hw", "retry_hw"],
                f"Resource-limit sweep (lci_b{{depth}}, {sweep_nodes} nodes)"))

    # -- receive-bound regime: recv_slots alongside lci_b{depth} (§3.1) ------
    base16 = sim_config_for_variant("lci_b16")
    recv_rows = []
    recv_sweep: dict = {}
    for rs in RECV_SWEEP:
        cfg = replace(base16, name=f"lci_b16_r{rs}", limits=base16.limits.variant(recv_slots=rs))
        r = octotiger(cfg, n_nodes=sweep_nodes, workers=workers,
                      total_subgrids=subgrids, timesteps=3, max_seconds=120.0)
        recv_sweep[rs] = {
            "elapsed": r.elapsed,
            "tasks": r.tasks,
            "rnr_events": r.rnr_events,
            "rnr_retries": r.rnr_retries,
            "backpressure_events": r.backpressure_events,
        }
        recv_rows.append({
            "recv_slots": rs or "unbounded",
            "elapsed": f"{r.elapsed*1e3:.2f}ms",
            "rnr_events": r.rnr_events,
            "tasks": r.tasks,
        })
    scarce, ample = recv_sweep[RECV_SWEEP[-1]], recv_sweep[RECV_SWEEP[1]]
    claims += [
        # receive-bound regime: scarce posted receives RNR (more than the
        # ample depth does) yet lose nothing — retransmission, not loss
        Claim("§3.1", "scarce recv_slots raise rnr_events but lose no parcels", 1.0,
              float(scarce["rnr_events"]
                    if (scarce["tasks"] == tasks_expected
                        and scarce["rnr_events"] > ample["rnr_events"]) else 0),
              direction="ordering"),
    ]
    print(table(recv_rows, ["recv_slots", "elapsed", "rnr_events", "tasks"],
                f"Receive-bound sweep (lci_b16 x recv_slots, {sweep_nodes} nodes)"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"elapsed": {k: {str(n): x for n, x in v.items()} for k, v in data.items()},
               "resource_sweep": {"n_nodes": sweep_nodes, "results": sweep},
               "recv_sweep": {"n_nodes": sweep_nodes,
                              "results": {str(k): v for k, v in recv_sweep.items()}},
               "claims": [c.row() for c in claims]}
    save_result("octotiger_scaling", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
