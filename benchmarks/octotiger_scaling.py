"""Paper Fig 4: Octo-Tiger strong scaling (lci vs mpi vs mpi_a)."""
from __future__ import annotations

import sys

from repro.amtsim.workloads import octotiger

from .common import Claim, save_result, table

NODES = (2, 8, 32, 128)


def run(fast: bool = False) -> dict:
    nodes = (2, 8, 32) if fast else NODES
    subgrids = 2048 if not fast else 512
    workers = 16 if not fast else 8
    rows = []
    data: dict = {}
    for v in ("lci", "mpi", "mpi_a"):
        e = {}
        for n in nodes:
            r = octotiger(v, n_nodes=n, workers=workers, total_subgrids=subgrids,
                          timesteps=3, max_seconds=120.0)
            e[n] = r.elapsed
        data[v] = e
        rows.append({"variant": v, **{f"n{n}": f"{e[n]*1e3:.2f}ms" for n in nodes}})
    nmax = nodes[-1]
    speedup_small = data["mpi"][nodes[0]] / data["lci"][nodes[0]]
    speedup_large = data["mpi"][nmax] / data["lci"][nmax]
    claims = [
        Claim("Fig4", "lci/mpi speedup at max nodes (paper up to 2x)", 1.3, speedup_large),
        Claim("Fig4", "speedup grows with node count", 1.0, speedup_large / speedup_small),
    ]
    print(table(rows, ["variant"] + [f"n{n}" for n in nodes], "Fig 4 Octo-Tiger strong scaling"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"elapsed": {k: {str(n): x for n, x in v.items()} for k, v in data.items()},
               "claims": [c.row() for c in claims]}
    save_result("octotiger_scaling", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
