"""Paper Fig 3b: latency microbenchmark (1 … 4096 concurrent chains)."""
from __future__ import annotations

import sys

from repro.amtsim.workloads import chains

from .common import Claim, save_result, table

CHAINS = (1, 16, 256, 1024)
VARIANTS = ("lci", "mpi", "mpi_a")


def run(fast: bool = False) -> dict:
    chain_counts = (1, 64, 256) if fast else CHAINS
    rows = []
    data: dict = {}
    for size, label in ((8, "8B"), (16384, "16KiB")):
        for v in VARIANTS:
            lat = {}
            for nc in chain_counts:
                r = chains(v, msg_size=size, nchains=nc, nsteps=20, nthreads=64,
                           max_seconds=5.0)
                lat[nc] = r.elapsed
            data[f"{v}_{label}"] = lat
            rows.append({"variant": v, "size": label,
                         **{f"c{n}": f"{lat[n]*1e6:.1f}us" for n in chain_counts}})
    c0 = chain_counts[0]
    cmax = chain_counts[-1]
    claims = [
        Claim("Fig3b", "lci 8B latency below mpi (paper up to 3x)", 1.5,
              data["mpi_8B"][c0] / data["lci_8B"][c0]),
        Claim("Fig3b", "lci 16KiB latency below mpi (paper up to 20x)", 1.5,
              data["mpi_16KiB"][cmax] / data["lci_16KiB"][cmax]),
        Claim("Fig3b", "lci sustains concurrent chains better than mpi", 1.0,
              (data["mpi_8B"][cmax] / data["mpi_8B"][c0])
              / max(data["lci_8B"][cmax] / data["lci_8B"][c0], 1e-9)),
    ]
    print(table(rows, ["variant", "size"] + [f"c{n}" for n in chain_counts], "Fig 3b latency"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"latency": {k: {str(n): x for n, x in v.items()} for k, v in data.items()},
               "claims": [c.row() for c in claims]}
    save_result("latency", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
