"""Paper Fig 3b: latency microbenchmark (1 … 4096 concurrent chains), plus
the eager-threshold latency sweep: a 16 KiB hop pays a rendezvous round trip
unless the protocol engine ships it eager through a bounce buffer."""
from __future__ import annotations

import sys
from dataclasses import replace

from repro.amtsim.parcelport_sim import sim_config_for_variant
from repro.amtsim.workloads import chains

from .common import Claim, save_result, table

CHAINS = (1, 16, 256, 1024)
VARIANTS = ("lci", "mpi", "mpi_a")
EAGER_THRESHOLDS = ((0, "noeager"), (8192, "8k"), (16384, "16k"), (65536, "64k"))


def eager_latency_sweep(fast: bool = False) -> tuple:
    """One-way 16 KiB hop latency as the eager threshold sweeps past it."""
    rows = []
    lat: dict = {}
    nsteps = 15 if fast else 30
    for thr, label in EAGER_THRESHOLDS:
        cfg = replace(sim_config_for_variant("lci"), name=f"lci_eager_{label}", eager_threshold=thr)
        r = chains(cfg, msg_size=16384, nchains=16, nsteps=nsteps, nthreads=16, max_seconds=5.0)
        lat[label] = r.elapsed
        rows.append({"threshold": label, "16KiB_hop": f"{r.elapsed*1e6:.2f}us"})
    claims = [
        Claim("§3.3", "eager (64k thr) cuts 16KiB hop latency vs rendezvous", 1.05,
              lat["noeager"] / max(lat["64k"], 1e-12)),
        # the threshold is inclusive: a 16 KiB message at a 16 KiB threshold
        # must already ship eager (same win as the 64k threshold)
        Claim("§3.3", "eager engages exactly at the threshold boundary", 1.05,
              lat["noeager"] / max(lat["16k"], 1e-12)),
    ]
    return rows, lat, claims


def run(fast: bool = False) -> dict:
    chain_counts = (1, 64, 256) if fast else CHAINS
    rows = []
    data: dict = {}
    for size, label in ((8, "8B"), (16384, "16KiB")):
        for v in VARIANTS:
            lat = {}
            for nc in chain_counts:
                r = chains(v, msg_size=size, nchains=nc, nsteps=20, nthreads=64,
                           max_seconds=5.0)
                lat[nc] = r.elapsed
            data[f"{v}_{label}"] = lat
            rows.append({"variant": v, "size": label,
                         **{f"c{n}": f"{lat[n]*1e6:.1f}us" for n in chain_counts}})
    c0 = chain_counts[0]
    cmax = chain_counts[-1]
    claims = [
        Claim("Fig3b", "lci 8B latency below mpi (paper up to 3x)", 1.5,
              data["mpi_8B"][c0] / data["lci_8B"][c0]),
        Claim("Fig3b", "lci 16KiB latency below mpi (paper up to 20x)", 1.5,
              data["mpi_16KiB"][cmax] / data["lci_16KiB"][cmax]),
        Claim("Fig3b", "lci sustains concurrent chains better than mpi", 1.0,
              (data["mpi_8B"][cmax] / data["mpi_8B"][c0])
              / max(data["lci_8B"][cmax] / data["lci_8B"][c0], 1e-9)),
    ]
    print(table(rows, ["variant", "size"] + [f"c{n}" for n in chain_counts], "Fig 3b latency"))
    e_rows, e_lat, e_claims = eager_latency_sweep(fast=fast)
    claims += e_claims
    print(table(e_rows, ["threshold", "16KiB_hop"], "Protocol engine: eager-threshold latency sweep"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"latency": {k: {str(n): x for n, x in v.items()} for k, v in data.items()},
               "eager_hop_latency": e_lat,
               "claims": [c.row() for c in claims]}
    save_result("latency", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
