"""Paper Fig 3b: latency microbenchmark (1 … 4096 concurrent chains), plus
the eager-threshold latency sweep (a 16 KiB hop pays a rendezvous round
trip unless the protocol engine ships it eager through a bounce buffer)
and the latency-side **crossover calibration** over the paper's Fig 3 size
ladder: per size, hop latency eager vs forced rendezvous — the calibrated
threshold is the largest size where eager still cuts the hop."""
from __future__ import annotations

import sys
from dataclasses import replace

from repro.amtsim.parcelport_sim import sim_config_for_variant
from repro.amtsim.workloads import chains

from .common import Claim, save_result, table

CHAINS = (1, 16, 256, 1024)
VARIANTS = ("lci", "mpi", "mpi_a")
EAGER_THRESHOLDS = ((0, "noeager"), (8192, "8k"), (16384, "16k"), (65536, "64k"))

# Fig 3 size ladder for the latency-side crossover calibration.
CROSSOVER_SIZES = (512, 4096, 8192, 16384, 32768, 65536)
CROSSOVER_CEILING = 128 * 1024


def eager_latency_sweep(fast: bool = False) -> tuple:
    """One-way 16 KiB hop latency as the eager threshold sweeps past it."""
    rows = []
    lat: dict = {}
    nsteps = 15 if fast else 30
    for thr, label in EAGER_THRESHOLDS:
        cfg = replace(sim_config_for_variant("lci"), name=f"lci_eager_{label}", eager_threshold=thr)
        r = chains(cfg, msg_size=16384, nchains=16, nsteps=nsteps, nthreads=16, max_seconds=5.0)
        lat[label] = r.elapsed
        rows.append({"threshold": label, "16KiB_hop": f"{r.elapsed*1e6:.2f}us"})
    claims = [
        Claim("§3.3", "eager (64k thr) cuts 16KiB hop latency vs rendezvous", 1.05,
              lat["noeager"] / max(lat["64k"], 1e-12)),
        # the threshold is inclusive: a 16 KiB message at a 16 KiB threshold
        # must already ship eager (same win as the 64k threshold)
        Claim("§3.3", "eager engages exactly at the threshold boundary", 1.05,
              lat["noeager"] / max(lat["16k"], 1e-12)),
    ]
    return rows, lat, claims


def crossover_latency_sweep(fast: bool = False) -> tuple:
    """Per Fig-3 size: one-way hop latency with the eager path wide open vs
    forced rendezvous.  Sizes at or under the 8 KiB piggyback limit ride
    the header in BOTH configs and tie exactly; the eager gain appears
    above it.  The calibrated threshold is the largest size where eager
    still cuts the hop."""
    rows = []
    gains: dict = {}
    nsteps = 12 if fast else 25
    for size in CROSSOVER_SIZES:
        lat_e = chains(
            replace(sim_config_for_variant("lci"), name="lci_xover_eager", eager_threshold=CROSSOVER_CEILING),
            msg_size=size, nchains=8, nsteps=nsteps, nthreads=8, max_seconds=5.0,
        ).elapsed
        lat_r = chains(
            replace(sim_config_for_variant("lci"), name="lci_xover_rdv", eager_threshold=0),
            msg_size=size, nchains=8, nsteps=nsteps, nthreads=8, max_seconds=5.0,
        ).elapsed
        gains[size] = lat_r / max(lat_e, 1e-12)
        rows.append({"size": f"{size}B" if size < 1024 else f"{size//1024}KiB",
                     "eager": f"{lat_e*1e6:.2f}us", "rendezvous": f"{lat_r*1e6:.2f}us",
                     "rdv/eager": f"{gains[size]:.2f}x"})
    winning = [s for s in CROSSOVER_SIZES if gains[s] >= 1.0]
    calibrated = max(winning) if winning else 0
    claims = [
        Claim("Fig3b", "latency crossover: eager wins at least up to 16KiB", 16384.0, float(calibrated)),
        Claim("Fig3b", "eager cuts the 16KiB hop (rendezvous round trip saved)", 1.05, gains[16384]),
    ]
    return rows, {"latency_gain_rdv_over_eager": gains, "calibrated_threshold": calibrated}, claims


def run(fast: bool = False) -> dict:
    chain_counts = (1, 64, 256) if fast else CHAINS
    rows = []
    data: dict = {}
    for size, label in ((8, "8B"), (16384, "16KiB")):
        for v in VARIANTS:
            lat = {}
            for nc in chain_counts:
                r = chains(v, msg_size=size, nchains=nc, nsteps=20, nthreads=64,
                           max_seconds=5.0)
                lat[nc] = r.elapsed
            data[f"{v}_{label}"] = lat
            rows.append({"variant": v, "size": label,
                         **{f"c{n}": f"{lat[n]*1e6:.1f}us" for n in chain_counts}})
    c0 = chain_counts[0]
    cmax = chain_counts[-1]
    claims = [
        Claim("Fig3b", "lci 8B latency below mpi (paper up to 3x)", 1.5,
              data["mpi_8B"][c0] / data["lci_8B"][c0]),
        Claim("Fig3b", "lci 16KiB latency below mpi (paper up to 20x)", 1.5,
              data["mpi_16KiB"][cmax] / data["lci_16KiB"][cmax]),
        Claim("Fig3b", "lci sustains concurrent chains better than mpi", 1.0,
              (data["mpi_8B"][cmax] / data["mpi_8B"][c0])
              / max(data["lci_8B"][cmax] / data["lci_8B"][c0], 1e-9)),
    ]
    print(table(rows, ["variant", "size"] + [f"c{n}" for n in chain_counts], "Fig 3b latency"))
    e_rows, e_lat, e_claims = eager_latency_sweep(fast=fast)
    claims += e_claims
    print(table(e_rows, ["threshold", "16KiB_hop"], "Protocol engine: eager-threshold latency sweep"))
    x_rows, x_data, x_claims = crossover_latency_sweep(fast=fast)
    claims += x_claims
    print(table(x_rows, ["size", "eager", "rendezvous", "rdv/eager"],
                f"Latency crossover (calibrated threshold: {x_data['calibrated_threshold']} B)"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"latency": {k: {str(n): x for n, x in v.items()} for k, v in data.items()},
               "eager_hop_latency": e_lat,
               "crossover": {"latency_gain_rdv_over_eager": {str(s): g for s, g in x_data["latency_gain_rdv_over_eager"].items()},
                             "calibrated_threshold": x_data["calibrated_threshold"]},
               "claims": [c.row() for c in claims]}
    save_result("latency", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
