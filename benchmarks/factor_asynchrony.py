"""Paper Fig 6 (§5.1): asynchrony — dynamic put vs send/recv header transfer.

Variants: base (put+queue), sendrecv_queue, sendrecv_sync.
Observation 1: one-sided put wins on small-message rate; an efficient
synchronizer-based send/recv closes most of the gap at the app level.
"""
from __future__ import annotations

import sys

from repro.amtsim.workloads import chains, flood, octotiger

from .common import Claim, save_result, table

VARIANTS = ("lci", "sendrecv_queue", "sendrecv_sync")


def run(fast: bool = False) -> dict:
    rows = []
    data: dict = {}
    for v in VARIANTS:
        rate8 = flood(v, msg_size=8, nthreads=64, nmsgs=4000).rate
        rate16k = flood(v, msg_size=16384, nthreads=64, nmsgs=2000).rate
        lat = chains(v, msg_size=8, nchains=256, nsteps=20, nthreads=64, max_seconds=5.0).elapsed
        app = octotiger(v, n_nodes=8, workers=8, total_subgrids=512, timesteps=3).elapsed
        data[v] = {"rate_8B": rate8, "rate_16KiB": rate16k, "latency": lat, "octotiger": app}
        rows.append({"variant": v, "rate8": f"{rate8/1e6:.2f}M/s",
                     "rate16k": f"{rate16k/1e3:.0f}k/s",
                     "latency": f"{lat*1e6:.1f}us", "octotiger": f"{app*1e3:.2f}ms"})
    base = data["lci"]
    claims = [
        Claim("Fig6", "send/recv costs small-message rate vs put (paper ~78% drop ⇒ ratio ≥1.5)",
              1.5, base["rate_8B"] / data["sendrecv_queue"]["rate_8B"]),
        Claim("Fig6", "synchronizer recovers most send/recv loss",
              1.0, data["sendrecv_sync"]["rate_8B"] / data["sendrecv_queue"]["rate_8B"]),
        Claim("Fig6", "no significant app-level impact (within 15%)",
              0.85, min(base["octotiger"] / data["sendrecv_sync"]["octotiger"],
                        data["sendrecv_sync"]["octotiger"] / base["octotiger"])),
    ]
    print(table(rows, ["variant", "rate8", "rate16k", "latency", "octotiger"], "Fig 6 asynchrony factors"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"data": data, "claims": [c.row() for c in claims]}
    save_result("factor_asynchrony", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
