"""Framework roofline report: per (arch × shape × mesh) terms from the
dry-run artifacts (deliverable g).  Not a paper figure — the framework's
own §Roofline deliverable."""
from __future__ import annotations

import sys
from pathlib import Path

from repro.roofline import format_table, load_cells

from .common import save_result, table


def run(fast: bool = False, dry_dir: str = "experiments/dryrun") -> dict:
    if not Path(dry_dir).exists():
        print(f"[roofline_report] {dry_dir} missing — run the dry-run sweep first:")
        print("  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes --remat full")
        return {"skipped": True}
    out = {}
    for mesh in ("16x16", "2x16x16"):
        cells = load_cells(dry_dir, mesh_filter=mesh)
        if not cells:
            continue
        cells.sort(key=lambda c: c.roofline_fraction)
        print(f"\n=== Roofline ({mesh}, {len(cells)} cells) ===")
        print(format_table(cells))
        out[mesh] = [
            {"cell": c.cell, "compute_s": c.compute_s, "memory_s": c.memory_s,
             "collective_s": c.collective_s, "dominant": c.dominant,
             "flops_ratio": c.flops_ratio, "roofline_fraction": c.roofline_fraction}
            for c in cells
        ]
    save_result("roofline_report", out)
    return out


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
