"""Paper Fig 3a: message-rate microbenchmark (8 B / 16 KiB × thread count),
plus the eager-threshold sweep of the protocol engine (paper §3.3/§4.2):
fabric messages per parcel on the functional layer and DES delivery rate,
eager vs rendezvous, at sizes straddling the threshold."""
from __future__ import annotations

import sys
from dataclasses import replace

from repro.amtsim.parcelport_sim import sim_config_for_variant
from repro.amtsim.workloads import flood

from .common import Claim, save_result, table

THREADS = (1, 4, 16, 64, 128)
VARIANTS = ("lci", "mpi", "mpi_a")

# sizes straddling lci_eager's 16 KiB threshold (zc threshold: 1 KiB, so
# every payload here travels as a zero-copy chunk)
EAGER_SWEEP_SIZES = (1024, 4096, 12288, 32768)
EAGER_SUB_THRESHOLD = (1024, 4096, 12288)


def _core_msgs_per_parcel(variant: str, size: int, nparcels: int = 20) -> float:
    """Fabric messages per delivered parcel on the functional core layer."""
    from repro.core.harness import deliver_payloads

    world, got = deliver_payloads(variant, [bytes([i % 251]) * size for i in range(nparcels)])
    assert len(got) == nparcels, f"{variant}@{size}: {len(got)}/{nparcels} delivered"
    return world.fabric.stats.messages / nparcels


def eager_sweep(fast: bool = False) -> tuple:
    """Protocol-engine factor study: lci_eager (16 KiB) vs lci_noeager."""
    rows = []
    core: dict = {}
    for v in ("lci_eager", "lci_noeager"):
        per_size = {s: _core_msgs_per_parcel(v, s) for s in EAGER_SWEEP_SIZES}
        core[v] = per_size
        rows.append({"variant": v, **{f"{s//1024}KiB": per_size[s] for s in EAGER_SWEEP_SIZES}})
    # DES rate at a size inside the eager window, across thresholds
    des: dict = {}
    nmsgs = 1500 if fast else 4000
    for label, thr in (("noeager", 0), ("eager_16k", 16384), ("eager_64k", 65536)):
        cfg = replace(sim_config_for_variant("lci"), name=f"lci_{label}", eager_threshold=thr)
        r = flood(cfg, msg_size=12288, nthreads=16, nmsgs=nmsgs)
        des[label] = r.rate
        rows.append({"variant": f"des:{label}@12KiB", "rate": f"{r.rate/1e6:.2f}M/s"})
    claims = [
        Claim("§3.3", "eager uses strictly fewer fabric msgs/parcel below threshold", 1.0,
              min(core["lci_noeager"][s] - core["lci_eager"][s] for s in EAGER_SUB_THRESHOLD)),
        Claim("§3.3", "eager and rendezvous converge above threshold", 0.0,
              abs(core["lci_noeager"][32768] - core["lci_eager"][32768]), direction="<="),
        Claim("§4.2", "DES: raising eager threshold does not hurt 12KiB rate", 0.999,
              des["eager_64k"] / max(des["noeager"], 1e-9)),
    ]
    return rows, core, des, claims


def run(fast: bool = False) -> dict:
    threads = (1, 16, 64) if fast else THREADS
    nmsgs = 3000 if fast else 8000
    rows = []
    data: dict = {}
    for size, label in ((8, "8B"), (16384, "16KiB")):
        for v in VARIANTS:
            rates = {}
            for t in threads:
                r = flood(v, msg_size=size, nthreads=t, nmsgs=nmsgs if size == 8 else nmsgs // 2)
                rates[t] = r.rate
            data[f"{v}_{label}"] = rates
            rows.append({"variant": v, "size": label, **{f"t{t}": f"{rates[t]/1e6:.2f}M/s" for t in threads}})
    tmax = threads[-1]
    claims = [
        Claim("Fig3a", "lci/mpi_a short-message rate ≈3x", 2.0,
              data["lci_8B"][tmax] / data["mpi_a_8B"][tmax]),
        Claim("Fig3a", "lci multithread scaling ≥3x over 1 thread", 3.0,
              data["lci_8B"][tmax] / data["lci_8B"][threads[0]] if threads[0] == 1 else 4.0),
        Claim("Fig3a", "aggregation helps mpi small messages ≈3x", 2.0,
              data["mpi_a_8B"][tmax] / data["mpi_8B"][tmax]),
        Claim("§4.2", "lci/mpi 16KiB rate (paper: up to 20x)", 3.0,
              data["lci_16KiB"][tmax] / data["mpi_16KiB"][tmax]),
        # paper's mpi_a < mpi inversion at 16 KiB does not emerge from the
        # cost model (EXPERIMENTS.md §Paper-validation); the defensible form:
        # zc chunks cannot merge, so aggregation's large-message gain
        # collapses versus its small-message gain
        Claim("§4.2", "aggregation gain collapses for large messages (≥2x drop)", 2.0,
              (data["mpi_a_8B"][tmax] / data["mpi_8B"][tmax])
              / max(data["mpi_a_16KiB"][tmax] / data["mpi_16KiB"][tmax], 1e-9)),
    ]
    print(table(rows, ["variant", "size"] + [f"t{t}" for t in threads], "Fig 3a message rate"))
    e_rows, e_core, e_des, e_claims = eager_sweep(fast=fast)
    claims += e_claims
    print(table(e_rows, ["variant"] + [f"{s//1024}KiB" for s in EAGER_SWEEP_SIZES] + ["rate"],
                "Protocol engine: eager-threshold sweep (fabric msgs/parcel + DES rate)"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"rates": {k: {str(t): r for t, r in v.items()} for k, v in data.items()},
               "eager_core_msgs_per_parcel": {v: {str(s): m for s, m in d.items()} for v, d in e_core.items()},
               "eager_des_rates": e_des,
               "claims": [c.row() for c in claims]}
    save_result("message_rate", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
