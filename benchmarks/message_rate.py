"""Paper Fig 3a: message-rate microbenchmark (8 B / 16 KiB × thread count),
plus the protocol-engine studies (paper §3.3/§4.2): the eager-threshold
sweep (fabric messages per parcel + DES delivery rate at sizes straddling
the threshold), the rate-side eager/rendezvous sweep over the paper's
Fig 3 size ladder (claim: eager never hurts delivery rate — the crossover
*calibration* lives in :mod:`benchmarks.latency`, where the rendezvous
round trip actually shows), and the **threshold-aware aggregation** study
(``lci_agg_eager`` must coalesce an eager-sized burst without spilling any
aggregate onto the rendezvous path)."""
from __future__ import annotations

import sys
from dataclasses import replace

from repro.amtsim.parcelport_sim import sim_config_for_variant
from repro.amtsim.workloads import flood

from .common import Claim, save_result, table

THREADS = (1, 4, 16, 64, 128)
VARIANTS = ("lci", "mpi", "mpi_a")

# sizes straddling lci_eager's 16 KiB threshold (zc threshold: 1 KiB, so
# every payload here travels as a zero-copy chunk)
EAGER_SWEEP_SIZES = (1024, 4096, 12288, 32768)
EAGER_SUB_THRESHOLD = (1024, 4096, 12288)

# The paper's Fig 3 ladder (8 B … 64 KiB): where does the eager/rendezvous
# crossover sit?  The calibrated threshold is the largest size at which
# shipping eager still beats the rendezvous round trip.
CROSSOVER_SIZES = (8, 64, 512, 4096, 8192, 16384, 32768, 65536)
CROSSOVER_CEILING = 128 * 1024  # eager threshold that covers the whole ladder


def _core_msgs_per_parcel(variant: str, size: int, nparcels: int = 20) -> float:
    """Fabric messages per delivered parcel on the functional core layer."""
    from repro.core.harness import deliver_payloads

    world, got = deliver_payloads(variant, [bytes([i % 251]) * size for i in range(nparcels)])
    assert len(got) == nparcels, f"{variant}@{size}: {len(got)}/{nparcels} delivered"
    return world.fabric.stats.messages / nparcels


def eager_sweep(fast: bool = False) -> tuple:
    """Protocol-engine factor study: lci_eager (16 KiB) vs lci_noeager."""
    rows = []
    core: dict = {}
    for v in ("lci_eager", "lci_noeager"):
        per_size = {s: _core_msgs_per_parcel(v, s) for s in EAGER_SWEEP_SIZES}
        core[v] = per_size
        rows.append({"variant": v, **{f"{s//1024}KiB": per_size[s] for s in EAGER_SWEEP_SIZES}})
    # DES rate at a size inside the eager window, across thresholds
    des: dict = {}
    nmsgs = 1500 if fast else 4000
    for label, thr in (("noeager", 0), ("eager_16k", 16384), ("eager_64k", 65536)):
        cfg = replace(sim_config_for_variant("lci"), name=f"lci_{label}", eager_threshold=thr)
        r = flood(cfg, msg_size=12288, nthreads=16, nmsgs=nmsgs)
        des[label] = r.rate
        rows.append({"variant": f"des:{label}@12KiB", "rate": f"{r.rate/1e6:.2f}M/s"})
    claims = [
        Claim("§3.3", "eager uses strictly fewer fabric msgs/parcel below threshold", 1.0,
              min(core["lci_noeager"][s] - core["lci_eager"][s] for s in EAGER_SUB_THRESHOLD)),
        Claim("§3.3", "eager and rendezvous converge above threshold", 0.0,
              abs(core["lci_noeager"][32768] - core["lci_eager"][32768]), direction="<="),
        Claim("§4.2", "DES: raising eager threshold does not hurt 12KiB rate", 0.999,
              des["eager_64k"] / max(des["noeager"], 1e-9)),
    ]
    return rows, core, des, claims


def crossover_sweep(fast: bool = False) -> tuple:
    """Rate-side crossover sweep over the paper's Fig 3 sizes: DES delivery
    rate with the eager path wide open vs forced rendezvous, per size.
    Flood throughput is wire-bound at large sizes, so eager and rendezvous
    tie there — the falsifiable rate-side claim is therefore *eager never
    hurts* (min ratio across the ladder), while the decisive crossover
    *calibration* comes from the latency sweep in :mod:`benchmarks.latency`
    (a rendezvous round trip is a latency cost, not a bandwidth cost)."""
    rows = []
    ratios: dict = {}
    nmsgs = 1200 if fast else 2500
    for size in CROSSOVER_SIZES:
        r_eager = flood(
            replace(sim_config_for_variant("lci"), name="lci_xover_eager", eager_threshold=CROSSOVER_CEILING),
            msg_size=size, nthreads=16, nmsgs=nmsgs,
        ).rate
        r_rdv = flood(
            replace(sim_config_for_variant("lci"), name="lci_xover_rdv", eager_threshold=0),
            msg_size=size, nthreads=16, nmsgs=nmsgs,
        ).rate
        ratios[size] = r_eager / max(r_rdv, 1e-9)
        rows.append({"size": f"{size}B" if size < 1024 else f"{size//1024}KiB",
                     "eager": f"{r_eager/1e6:.2f}M/s", "rendezvous": f"{r_rdv/1e6:.2f}M/s",
                     "eager/rdv": f"{ratios[size]:.2f}x"})
    claims = [
        # falsifiable on a wire-bound flood: if eager were strictly worse at
        # ANY size, the min ratio drops below 1 and this reports PARTIAL
        Claim("Fig3", "eager never hurts delivery rate at any Fig 3 size", 0.999,
              min(ratios.values())),
    ]
    return rows, {"ratios": ratios}, claims


def agg_threshold_study() -> tuple:
    """Threshold-aware aggregation on the functional core: a burst of
    eager-sized same-destination parcels must coalesce into eager-only
    aggregates under ``lci_agg_eager``, while the unbounded merge spills the
    pile over the threshold onto the rendezvous path."""
    from repro.core.lci_parcelport import LCIParcelport
    from repro.core.parcel import serialize_action
    from repro.core.parcelport import World
    from repro.core.variants import VARIANTS

    rows = []
    stats: dict = {}
    nparcels, payload = 32, 3_000
    for label, cfg in (
        ("agg_unbounded", VARIANTS["lci_agg_eager"].variant(name="lci_agg_unbounded", agg_eager=False)),
        ("agg_eager", VARIANTS["lci_agg_eager"]),
    ):
        world = World(2, lambda loc, fab: LCIParcelport(loc, fab, cfg), devices_per_rank=cfg.ndevices)
        got: list = []
        world.localities[1].register_action("sink", lambda *a: got.append(a))
        pp = world.localities[0].parcelport
        parcels = [
            serialize_action(1 + i, 0, 1, "sink", (bytes([i]) * payload,), zero_copy_threshold=1 << 30)
            for i in range(nparcels)
        ]
        # pre-load the per-destination queue (as concurrent senders would),
        # then one send drains the lot through the batching logic
        from collections import deque

        q = pp._agg_queues.setdefault(1, deque())
        for p in parcels[:-1]:
            q.append((p, None))
        pp.send(1, parcels[-1])
        world.drain()
        assert len(got) == nparcels, f"{label}: {len(got)}/{nparcels} delivered"
        st = world.fabric.stats
        stats[label] = {"eager": st.eager_msgs, "rendezvous": st.rendezvous_msgs}
        rows.append({"variant": label, "eager_msgs": st.eager_msgs, "rendezvous_msgs": st.rendezvous_msgs})
    claims = [
        Claim("§2.2.2", "threshold-aware aggregation never spills into rendezvous", 0.0,
              float(stats["agg_eager"]["rendezvous"]), direction="<="),
        Claim("§2.2.2", "unbounded merge of the same burst does spill", 1.0,
              float(stats["agg_unbounded"]["rendezvous"])),
    ]
    return rows, stats, claims


def collective_study() -> tuple:
    """The CollectiveComm backend (the serving stack's transport, ISSUE 5)
    against lci/mpi on the functional layer: identical parcel workloads
    through identical parcelport logic, message counts read from whichever
    transport carried the bytes, plus the bounded serving hand-off
    (EAGAIN + retry, §3.3.4) and aggregation over the collective path."""
    from collections import deque

    from repro.core.comm.collective import CollectiveParcelport
    from repro.core.comm.resources import ResourceLimits
    from repro.core.harness import deliver_payloads, transport_stats
    from repro.core.parcel import serialize_action
    from repro.core.parcelport import World
    from repro.core.variants import VARIANTS

    rows = []
    nparcels = 20
    per_variant: dict = {}
    for v in ("collective", "lci", "mpi"):
        per_size = {}
        for size in EAGER_SWEEP_SIZES:
            world, got = deliver_payloads(v, [bytes([i % 251]) * size for i in range(nparcels)])
            assert len(got) == nparcels, f"{v}@{size}: {len(got)}/{nparcels}"
            per_size[size] = transport_stats(world).messages / nparcels
        per_variant[v] = per_size
        rows.append({"variant": v, **{f"{s//1024}KiB": per_size[s] for s in EAGER_SWEEP_SIZES}})
    # bounded hand-off: a tight shared ResourceLimits must surface EAGAIN
    # on the collective transport AND still deliver everything
    lim = ResourceLimits(send_queue_depth=2, bounce_buffers=2, bounce_buffer_size=65_536)
    world, got = deliver_payloads(
        "collective", [bytes([i]) * 600 for i in range(40)], fabric_kwargs={"limits": lim}
    )
    bounded = {
        "delivered": len(got),
        "backpressure_events": transport_stats(world).backpressure_events,
        "parks": sum(loc.parcelport.stats_backpressure_parks for loc in world.localities),
    }
    rows.append({"variant": "collective(bounded b2)", **bounded})
    # aggregation on the collective path: a preloaded burst of eager-sized
    # same-destination parcels coalesces into far fewer transport messages
    agg_msgs = {}
    for label, cfg in (
        ("plain", VARIANTS["collective"]),
        ("agg", VARIANTS["collective"].variant(name="collective_agg", aggregation=True)),
    ):
        world = World(
            2,
            lambda loc, fab, _c=cfg: CollectiveParcelport(loc, fab, _c),
            devices_per_rank=cfg.ndevices,
        )
        got2: list = []
        world.localities[1].register_action("sink", lambda *a: got2.append(a))
        pp = world.localities[0].parcelport
        parcels = [
            serialize_action(1 + i, 0, 1, "sink", (bytes([i]) * 600,), zero_copy_threshold=1 << 30)
            for i in range(16)
        ]
        if cfg.aggregation:
            # pre-load the per-destination queue (as concurrent senders
            # would); one send drains the lot through the batching logic
            q = pp._agg_queues.setdefault(1, deque())
            for p in parcels[:-1]:
                q.append((p, None))
            pp.send(1, parcels[-1])
        else:
            for p in parcels:
                pp.send(1, p)
        world.drain()
        assert len(got2) == 16, f"collective {label}: {len(got2)}/16"
        agg_msgs[label] = transport_stats(world).messages
        rows.append({"variant": f"collective_{label}_burst", "messages": agg_msgs[label]})
    claims = [
        Claim("§2.3", "collective backend never costs extra messages/parcel vs lci", 1.0,
              max(per_variant["collective"][s] / per_variant["lci"][s] for s in EAGER_SWEEP_SIZES),
              direction="<="),
        Claim("§3.3.4", "bounded collective hand-off surfaces EAGAIN backpressure", 1.0,
              float(min(bounded["backpressure_events"], bounded["parks"]))),
        Claim("§3.3.4", "bounded collective hand-off throttles, loses nothing", 1.0,
              bounded["delivered"] / 40.0),
        Claim("§2.2.2", "aggregation over collective coalesces a 16-parcel burst ≥4x", 4.0,
              agg_msgs["plain"] / max(agg_msgs["agg"], 1)),
    ]
    data = {"msgs_per_parcel": {v: {str(s): m for s, m in d.items()} for v, d in per_variant.items()},
            "bounded": bounded, "agg_burst_messages": agg_msgs}
    return rows, data, claims


def capability_ladder(fast: bool = False) -> tuple:
    """ISSUE 6: the paper's §3.3.1 capability ladder on ONE shared-memory
    transport — two-sided emulation (``shmem``), true put-with-signal
    (``shmem_put``), put + queue completion (``shmem_putq``) — same
    protocol engine, selection purely by ``Capabilities``.  Functional
    layer: every rung must deliver bit-identical payloads to ``lci`` at
    every size, and the put rungs must genuinely ride one-sided puts
    (header puts counted by the transport).  DES layer: 16-thread 8 B
    flood rates must reproduce the ladder ordering — queue completion
    beats the serialized signal scan beats tag matching."""
    from repro.core.harness import deliver_payloads, transport_stats

    rungs = ("shmem", "shmem_put", "shmem_putq")
    sizes = (8, 600, 3000, 12288, 40960)
    nparcels = 12
    rows = []
    parity: dict = {}
    puts_per_parcel: dict = {}

    def _arrived(variant: str, size: int):
        world, got = deliver_payloads(
            variant, [bytes([(i * 7 + size) % 251]) * size for i in range(nparcels)]
        )
        assert len(got) == nparcels, f"{variant}@{size}: {len(got)}/{nparcels}"
        return world, sorted(a[0] for a in got)

    for v in rungs:
        per_size = {}
        for size in sizes:
            _, ref = _arrived("lci", size)
            world, got = _arrived(v, size)
            parity[(v, size)] = 1.0 if got == ref else 0.0
            st = transport_stats(world)
            per_size[size] = st.puts / nparcels
        puts_per_parcel[v] = per_size
        rows.append({"variant": v,
                     **{f"{s}B" if s < 1024 else f"{s//1024}KiB": f"{per_size[s]:.2f}"
                        for s in sizes}})
    # DES: the rate ladder under a 16-thread short-message flood
    nmsgs = 1200 if fast else 3000
    rates = {v: flood(sim_config_for_variant(v), msg_size=8, nthreads=16, nmsgs=nmsgs).rate
             for v in rungs}
    for v in rungs:
        rows.append({"variant": f"des:{v}@8B", "rate": f"{rates[v]/1e6:.2f}M/s"})
    claims = [
        Claim("§3.3.1", "ladder: put+queue-completion ≥ put-signal (DES rate)", 0.999,
              rates["shmem_putq"] / max(rates["shmem_put"], 1e-9)),
        Claim("§3.3.1", "ladder: put-signal ≥ two-sided emulation (DES rate)", 0.999,
              rates["shmem_put"] / max(rates["shmem"], 1e-9)),
        Claim("§3.3.1", "one-sided put ≥2x two-sided emulation, 16-thread flood", 2.0,
              rates["shmem_putq"] / max(rates["shmem"], 1e-9)),
        Claim("§2.3", "every shmem rung delivers bit-identical payloads to lci", 1.0,
              min(parity.values())),
        Claim("§3.3.1", "put rungs genuinely ride one-sided puts (≥1 header put/parcel)", 1.0,
              min(min(puts_per_parcel[v].values()) for v in ("shmem_put", "shmem_putq"))),
        Claim("§3.3.1", "the two-sided rung issues zero puts", 0.0,
              max(puts_per_parcel["shmem"].values()), direction="<="),
    ]
    data = {"puts_per_parcel": {v: {str(s): p for s, p in d.items()}
                                for v, d in puts_per_parcel.items()},
            "delivery_parity_vs_lci": {f"{v}@{s}": p for (v, s), p in parity.items()},
            "des_rates": rates}
    return rows, data, claims


def progress_contention(fast: bool = False, smoke: bool = False) -> tuple:
    """Progress-policy × worker-count ladder (paper §5.3 / §3.3.4) on the
    ONE shared ProgressEngine: worker-polling implicit, explicit lock-free,
    explicit under a coarse try lock, the blocking-lock "catastrophic"
    combination, dedicated progress workers (``lci_prg2``), and the
    per-device completion-router scope — all the same engine, different
    :class:`~repro.core.comm.progress.ProgressPolicy` / router."""
    from dataclasses import replace as _replace

    from repro.core.device import LockMode

    threads = (4, 16) if smoke else ((8, 32) if fast else (8, 32, 64))
    nmsgs = 400 if smoke else (1200 if fast else 2500)
    base = sim_config_for_variant("lci")
    policies = {
        "prg0_explicit": sim_config_for_variant("lci_prg0"),  # all workers poll
        "implicit": _replace(base, name="lci_implicit", progress_mode="implicit"),
        "try_explicit": _replace(base, name="lci_try_explicit", lock_mode=LockMode.TRY),
        # §5.3's catastrophe: blocking lock + eager explicit progress
        "block_explicit": _replace(base, name="lci_block_explicit", lock_mode=LockMode.BLOCK),
        "prg2_dedicated": sim_config_for_variant("lci_prg2"),
        "devcq_explicit": _replace(base, name="lci_devcq", cq_scope="device"),
    }
    rows = []
    data: dict = {}
    for label, cfg in policies.items():
        rates = {t: flood(cfg, msg_size=8, nthreads=t, nmsgs=nmsgs).rate for t in threads}
        data[label] = rates
        rows.append({"policy": label, **{f"t{t}": f"{rates[t]/1e6:.2f}M/s" for t in threads}})
    t0, tmax = threads[0], threads[-1]
    claims = [
        Claim("§5.3", "blocking-lock + eager explicit progress is the worst policy", 1.0,
              min(r[tmax] for k, r in data.items() if k != "block_explicit")
              / max(data["block_explicit"][tmax], 1e-9)),
        Claim("§5.3", "explicit progress never loses to implicit worker-polling", 0.98,
              data["prg0_explicit"][tmax] / max(data["implicit"][tmax], 1e-9)),
        Claim("§3.3.4", "dedicated progress workers not justified (<=1.1x all-poll)", 1.1,
              data["prg2_dedicated"][tmax] / max(data["prg0_explicit"][tmax], 1e-9),
              direction="<="),
        Claim("§5.3", "lock-free scales with workers at least as well as blocking", 1.0,
              (data["prg0_explicit"][tmax] / data["prg0_explicit"][t0])
              / max(data["block_explicit"][tmax] / data["block_explicit"][t0], 1e-9)),
    ]
    return rows, {"threads": list(threads), "rates": data}, claims


def fleet_study(fast: bool = False) -> tuple:
    """ISSUE 7: the router + sharded-KV worker fleet over the comm layer.

    Three falsifiable claims on the tinyllama smoke model: (1) the
    N-worker fleet's goodput (tokens per engine step) matches the
    single-host server on a slot-saturating decode workload — sharding
    the KV slots across workers costs no step-rate; (2) chunked prefill
    bounds the worst per-step prefill burst (prompt tokens of work
    attributed to one step — the deterministic proxy for the p99 decode
    gap a monolithic prefill punches into co-scheduled streams) by ≥4x
    vs single-shot; (3) an admission storm against depth-1 workers
    surfaces EAGAIN refusals AND completes every request — typed
    backpressure re-queues, never drops."""
    import jax

    from repro.configs import SMOKES
    from repro.models import init_params
    from repro.serve import Fleet, FleetConfig, InferenceServer, ServeConfig

    arch = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), arch)
    # decode length is fixed regardless of --fast: the goodput claim needs
    # the decode-dominated regime (short runs let single-shot prefill's
    # free first token inflate the single-host tokens/step baseline)
    max_new = 24
    nreq = 8
    prompts = [[(7 * i + j) % arch.vocab_size for j in range(48)] for i in range(nreq)]

    def _single(chunk=0):
        srv = InferenceServer(arch, params, ServeConfig(
            slots=4, context=128, transport="inline", prefill_chunk=chunk))
        reqs = [srv.submit(p, max_new=max_new) for p in prompts]
        srv.run_until_idle()
        assert all(r.done_event.is_set() for r in reqs)
        burst = srv.core.max_prefill_burst
        return [r.out_tokens for r in reqs], srv.tokens_out / srv.steps, burst

    def _fleet(workers, chunk=0, depth=2, transport="collective"):
        fl = Fleet(arch, params, FleetConfig(
            workers=workers, slots=4, context=128, transport=transport,
            prefill_chunk=chunk, admission_depth=depth))
        try:
            reqs = [fl.submit(p, max_new=max_new) for p in prompts]
            fl.run_until_idle()
            done = sum(r.done_event.is_set() for r in reqs)
            burst = max(w.core.max_prefill_burst for w in fl.workers)
            return {
                "tokens": [r.out_tokens for r in reqs], "done": done,
                "goodput": fl.tokens_out / fl.steps, "burst": burst,
                "eagain": fl.eagain_events, "requeues": fl.requeues,
                "completed": fl.completed,
            }
        finally:
            fl.close()

    ref, single_goodput, single_burst = _single()
    base = _fleet(2)
    chunked = _fleet(2, chunk=4)
    storm = _fleet(2, depth=1)
    assert base["tokens"] == ref and storm["tokens"] == ref  # exactness gate
    rows = [
        {"tier": "single-host", "goodput": f"{single_goodput:.2f} tok/step",
         "prefill_burst": single_burst, "eagain": 0, "done": f"{nreq}/{nreq}"},
        {"tier": "fleet w=2", "goodput": f"{base['goodput']:.2f} tok/step",
         "prefill_burst": base["burst"], "eagain": base["eagain"],
         "done": f"{base['done']}/{nreq}"},
        {"tier": "fleet w=2 chunk=4", "goodput": f"{chunked['goodput']:.2f} tok/step",
         "prefill_burst": chunked["burst"], "eagain": chunked["eagain"],
         "done": f"{chunked['done']}/{nreq}"},
        {"tier": "fleet w=2 depth=1 storm", "goodput": f"{storm['goodput']:.2f} tok/step",
         "prefill_burst": storm["burst"], "eagain": storm["eagain"],
         "done": f"{storm['done']}/{nreq}"},
    ]
    claims = [
        Claim("§3.3.4", "fleet goodput ≥0.95x single-host, slot-saturating decode", 0.95,
              base["goodput"] / single_goodput),
        Claim("§2.2.2", "chunked prefill bounds worst per-step prefill burst ≥4x", 4.0,
              base["burst"] / max(chunked["burst"], 1)),
        Claim("§3.3.4", "fleet admission storm surfaces per-worker EAGAIN", 1.0,
              float(storm["eagain"])),
        Claim("§3.3.4", "fleet admission storm drops nothing (re-queue semantics)", 1.0,
              storm["completed"] / nreq),
    ]
    data = {"single_goodput": single_goodput, "single_burst": single_burst,
            "fleet": {k: {kk: vv for kk, vv in v.items() if kk != "tokens"}
                      for k, v in (("base", base), ("chunked", chunked), ("storm", storm))}}
    return rows, data, claims


def elasticity_study(fast: bool = False) -> tuple:
    """ISSUE 8: elastic progress capacity on the DES (``lci_eprg{lo}_{hi}``).

    A compute-heavy octree workload (task workers busy ~40 µs per task, so
    nobody polls the engine promptly — the §5.3 starvation regime) under
    three controllers: the fixed all-workers-poll baseline (``lci_prg0``),
    the hysteresis+cooldown elastic controller, and the naive
    single-threshold controller.  Three falsifiable claims: (1) elastic
    scale-up under the storm cuts p99 hardware-CQ residency vs the fixed
    baseline; (2) hysteresis + cooldown bound the resize count well below
    the naive controller's thrash on the same signal; (3) every task
    completes through dozens of live grow/drain cycles — elasticity loses
    nothing.  (The workload is already CI-sized; ``fast`` changes nothing,
    keeping the claim values identical across CI legs.)"""
    del fast
    from repro.amtsim.workloads import octotiger

    base = sim_config_for_variant("lci_prg0")
    elastic_cfg = replace(base, name="lci_eprg0_2", elastic_progress=(0, 2))
    naive_cfg = replace(elastic_cfg, name="lci_eprg0_2_naive", elastic_hysteresis=False)
    kw = dict(n_nodes=2, workers=6, total_subgrids=96, timesteps=8, task_compute=40e-6)
    target = kw["total_subgrids"] * kw["timesteps"]
    runs = {
        "fixed_prg0": octotiger(base, **kw),
        "elastic_hysteresis": octotiger(elastic_cfg, **kw),
        "elastic_naive": octotiger(naive_cfg, **kw),
    }
    rows = [
        {"controller": label, "p99_reap": f"{r.reap_p99*1e6:.1f}us",
         "reap_ewma": f"{r.reap_ewma*1e6:.2f}us", "resizes": r.resizes,
         "tasks": f"{r.tasks}/{target}", "elapsed": f"{r.elapsed*1e3:.2f}ms"}
        for label, r in runs.items()
    ]
    fixed, elastic, naive = runs["fixed_prg0"], runs["elastic_hysteresis"], runs["elastic_naive"]
    claims = [
        Claim("§5.3", "elastic scale-up cuts p99 reap latency ≥1.5x vs fixed prg0", 1.5,
              fixed.reap_p99 / max(elastic.reap_p99, 1e-12)),
        Claim("§5.3", "hysteresis+cooldown bound resizes ≥2x below naive thrash", 2.0,
              naive.resizes / max(elastic.resizes, 1)),
        Claim("§5.3", "every task completes through live resize cycles (zero loss)", 1.0,
              min(elastic.tasks, naive.tasks) / target),
    ]
    data = {label: {"reap_p99": r.reap_p99, "reap_ewma": r.reap_ewma,
                    "reap_high": r.reap_high, "resizes": r.resizes,
                    "tasks": r.tasks, "elapsed": r.elapsed}
            for label, r in runs.items()}
    return rows, data, claims


def run(fast: bool = False) -> dict:
    threads = (1, 16, 64) if fast else THREADS
    nmsgs = 3000 if fast else 8000
    rows = []
    data: dict = {}
    for size, label in ((8, "8B"), (16384, "16KiB")):
        for v in VARIANTS:
            rates = {}
            for t in threads:
                r = flood(v, msg_size=size, nthreads=t, nmsgs=nmsgs if size == 8 else nmsgs // 2)
                rates[t] = r.rate
            data[f"{v}_{label}"] = rates
            rows.append({"variant": v, "size": label, **{f"t{t}": f"{rates[t]/1e6:.2f}M/s" for t in threads}})
    tmax = threads[-1]
    claims = [
        Claim("Fig3a", "lci/mpi_a short-message rate ≈3x", 2.0,
              data["lci_8B"][tmax] / data["mpi_a_8B"][tmax]),
        Claim("Fig3a", "lci multithread scaling ≥3x over 1 thread", 3.0,
              data["lci_8B"][tmax] / data["lci_8B"][threads[0]] if threads[0] == 1 else 4.0),
        Claim("Fig3a", "aggregation helps mpi small messages ≈3x", 2.0,
              data["mpi_a_8B"][tmax] / data["mpi_8B"][tmax]),
        Claim("§4.2", "lci/mpi 16KiB rate (paper: up to 20x)", 3.0,
              data["lci_16KiB"][tmax] / data["mpi_16KiB"][tmax]),
        # paper's mpi_a < mpi inversion at 16 KiB does not emerge from the
        # cost model (EXPERIMENTS.md §Paper-validation); the defensible form:
        # zc chunks cannot merge, so aggregation's large-message gain
        # collapses versus its small-message gain
        Claim("§4.2", "aggregation gain collapses for large messages (≥2x drop)", 2.0,
              (data["mpi_a_8B"][tmax] / data["mpi_8B"][tmax])
              / max(data["mpi_a_16KiB"][tmax] / data["mpi_16KiB"][tmax], 1e-9)),
    ]
    print(table(rows, ["variant", "size"] + [f"t{t}" for t in threads], "Fig 3a message rate"))
    e_rows, e_core, e_des, e_claims = eager_sweep(fast=fast)
    claims += e_claims
    print(table(e_rows, ["variant"] + [f"{s//1024}KiB" for s in EAGER_SWEEP_SIZES] + ["rate"],
                "Protocol engine: eager-threshold sweep (fabric msgs/parcel + DES rate)"))
    x_rows, x_data, x_claims = crossover_sweep(fast=fast)
    claims += x_claims
    print(table(x_rows, ["size", "eager", "rendezvous", "eager/rdv"],
                "Eager vs rendezvous delivery rate (Fig 3 sizes; crossover calibrated in latency.py)"))
    a_rows, a_stats, a_claims = agg_threshold_study()
    claims += a_claims
    print(table(a_rows, ["variant", "eager_msgs", "rendezvous_msgs"],
                "Threshold-aware aggregation (32 x 3000B burst, 16KiB threshold)"))
    c_rows, c_data, c_claims = collective_study()
    claims += c_claims
    print(table(c_rows, ["variant"] + [f"{s//1024}KiB" for s in EAGER_SWEEP_SIZES]
                + ["messages", "delivered", "backpressure_events", "parks"],
                "Collective backend vs lci/mpi (msgs/parcel, bounded hand-off, aggregation)"))
    l_rows, l_data, l_claims = capability_ladder(fast=fast)
    claims += l_claims
    print(table(l_rows, ["variant"]
                + [f"{s}B" if s < 1024 else f"{s//1024}KiB" for s in (8, 600, 3000, 12288, 40960)]
                + ["rate"],
                "Capability ladder on shmem (header puts/parcel + DES 8B flood rate)"))
    p_rows, p_data, p_claims = progress_contention(fast=fast)
    claims += p_claims
    print(table(p_rows, ["policy"] + [f"t{t}" for t in p_data["threads"]],
                "Progress-policy x worker-count ladder (§5.3, one shared engine)"))
    f_rows, f_data, f_claims = fleet_study(fast=fast)
    claims += f_claims
    print(table(f_rows, ["tier", "goodput", "prefill_burst", "eagain", "done"],
                "Serving fleet: router + sharded-KV workers over the comm layer (ISSUE 7)"))
    el_rows, el_data, el_claims = elasticity_study(fast=fast)
    claims += el_claims
    print(table(el_rows, ["controller", "p99_reap", "reap_ewma", "resizes", "tasks", "elapsed"],
                "Elastic progress capacity (ISSUE 8): fixed vs hysteresis vs naive"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"rates": {k: {str(t): r for t, r in v.items()} for k, v in data.items()},
               "eager_core_msgs_per_parcel": {v: {str(s): m for s, m in d.items()} for v, d in e_core.items()},
               "eager_des_rates": e_des,
               "crossover": {"rate_ratio_eager_over_rdv": {str(s): r for s, r in x_data["ratios"].items()}},
               "agg_threshold": a_stats,
               "collective": c_data,
               "capability_ladder": l_data,
               "fleet": f_data,
               "elasticity": el_data,
               "progress_contention": {"threads": p_data["threads"],
                                       "rates": {k: {str(t): r for t, r in v.items()}
                                                 for k, v in p_data["rates"].items()}},
               "claims": [c.row() for c in claims]}
    save_result("message_rate", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
