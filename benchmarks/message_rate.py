"""Paper Fig 3a: message-rate microbenchmark (8 B / 16 KiB × thread count)."""
from __future__ import annotations

import sys

from repro.amtsim.workloads import flood

from .common import Claim, save_result, table

THREADS = (1, 4, 16, 64, 128)
VARIANTS = ("lci", "mpi", "mpi_a")


def run(fast: bool = False) -> dict:
    threads = (1, 16, 64) if fast else THREADS
    nmsgs = 3000 if fast else 8000
    rows = []
    data: dict = {}
    for size, label in ((8, "8B"), (16384, "16KiB")):
        for v in VARIANTS:
            rates = {}
            for t in threads:
                r = flood(v, msg_size=size, nthreads=t, nmsgs=nmsgs if size == 8 else nmsgs // 2)
                rates[t] = r.rate
            data[f"{v}_{label}"] = rates
            rows.append({"variant": v, "size": label, **{f"t{t}": f"{rates[t]/1e6:.2f}M/s" for t in threads}})
    tmax = threads[-1]
    claims = [
        Claim("Fig3a", "lci/mpi_a short-message rate ≈3x", 2.0,
              data["lci_8B"][tmax] / data["mpi_a_8B"][tmax]),
        Claim("Fig3a", "lci multithread scaling ≥3x over 1 thread", 3.0,
              data["lci_8B"][tmax] / data["lci_8B"][threads[0]] if threads[0] == 1 else 4.0),
        Claim("Fig3a", "aggregation helps mpi small messages ≈3x", 2.0,
              data["mpi_a_8B"][tmax] / data["mpi_8B"][tmax]),
        Claim("§4.2", "lci/mpi 16KiB rate (paper: up to 20x)", 3.0,
              data["lci_16KiB"][tmax] / data["mpi_16KiB"][tmax]),
        # paper's mpi_a < mpi inversion at 16 KiB does not emerge from the
        # cost model (EXPERIMENTS.md §Paper-validation); the defensible form:
        # zc chunks cannot merge, so aggregation's large-message gain
        # collapses versus its small-message gain
        Claim("§4.2", "aggregation gain collapses for large messages (≥2x drop)", 2.0,
              (data["mpi_a_8B"][tmax] / data["mpi_8B"][tmax])
              / max(data["mpi_a_16KiB"][tmax] / data["mpi_16KiB"][tmax], 1e-9)),
    ]
    print(table(rows, ["variant", "size"] + [f"t{t}" for t in threads], "Fig 3a message rate"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"rates": {k: {str(t): r for t, r in v.items()} for k, v in data.items()},
               "claims": [c.row() for c in claims]}
    save_result("message_rate", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
