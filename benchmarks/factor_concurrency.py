"""Paper Fig 7 (§5.2): concurrency — completion queue vs synchronizer pool,
and the queue implementation (LCRQ vs Michael-Scott vs lock-based).

Observation 2: queue-based completion beats request pools, but only a
highly optimized MPMC queue realizes the benefit.
"""
from __future__ import annotations

import sys

from repro.amtsim.workloads import flood, octotiger

from .common import Claim, save_result, table

VARIANTS = ("lci", "sync", "queue_lock", "queue_ms")


def run(fast: bool = False) -> dict:
    rows = []
    data: dict = {}
    for v in VARIANTS:
        rate8 = flood(v, msg_size=8, nthreads=64, nmsgs=4000).rate
        rate16k = flood(v, msg_size=16384, nthreads=64, nmsgs=2000).rate
        app = octotiger(v, n_nodes=8, workers=8, total_subgrids=512, timesteps=3).elapsed
        data[v] = {"rate_8B": rate8, "rate_16KiB": rate16k, "octotiger": app}
        rows.append({"variant": v, "rate8": f"{rate8/1e6:.2f}M/s",
                     "rate16k": f"{rate16k/1e3:.0f}k/s", "octotiger": f"{app*1e3:.2f}ms"})
    claims = [
        Claim("Fig7", "synchronizer pool drops large-parcel rate (paper ~20%)",
              1.1, data["lci"]["rate_16KiB"] / data["sync"]["rate_16KiB"]),
        Claim("Fig7", "lock-based queue is not enough (LCRQ beats it)",
              1.1, data["lci"]["rate_8B"] / data["queue_lock"]["rate_8B"]),
        Claim("Fig7", "Michael-Scott queue is not enough (LCRQ beats it)",
              1.02, data["lci"]["rate_8B"] / data["queue_ms"]["rate_8B"]),
    ]
    print(table(rows, ["variant", "rate8", "rate16k", "octotiger"], "Fig 7 concurrency factors"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"data": data, "claims": [c.row() for c in claims]}
    save_result("factor_concurrency", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
