"""Device data plane (§Perf): fused quantize+pack vs the replaced host
gradient-sync pipeline, plus the roofline placement of the fused kernel.

The replaced pipeline did three walks over the gradient tree — the
``compress_grads_int8_ef`` per-leaf jit map, the ``tree.transpose`` split,
and a host ``pack_grads`` of the *dequantized f32* leaves — and shipped
f32 bytes.  The fused path (:mod:`repro.kernels.grad_pack`) does the
error-feedback add + per-tensor int8 quantize + pack in ONE compiled
program emitting one flat device buffer, and ships int8 + scales: ~4x
fewer wire bytes and one device→host transfer.

Claims (wired into ``--claims-strict`` CI):

* throughput — fused pack beats the replaced pipeline by >=2x at the
  4 MiB gradient point (transformer-like tree, d=88 x 12 layers);
* wire bytes — the quantized wire is >=3.5x smaller than the f32 wire;
* roofline — the fused kernel is bandwidth-bound on the deployment HW
  model (:class:`repro.roofline.analysis.HW`): arithmetic intensity far
  below the ridge, memory term >=90% of the modeled kernel time.  The
  flop/byte counts are per element: 9 f32 ops (ef-add, abs, max, div,
  round, 2x clip, sub, mul) over 13 bytes moved (read g + ef, write q +
  ef), AI ~= 0.69 — two decimal orders under the ridge, so the kernel's
  job is to saturate HBM, which is exactly what the single fused pass
  over tiles is for.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.grad_pack import pack_grads_fused, unpack_grads_fused
from repro.roofline.analysis import HW
from repro.train.grad_sync import compress_grads_int8_ef, pack_grads

from .common import Claim, save_result, table

# (d, layers) ladder of transformer-like gradient trees; the 4 MiB point
# (d=88, 12 layers, 72 leaves, 4.26 MiB of f32 gradients) carries the
# throughput claim.
LADDER = ((40, 6), (88, 12), (120, 12))
CLAIM_POINT = (88, 12)

# Fused-kernel roofline accounting, per gradient element (f32):
#   flops: ef-add, abs, max-reduce, divide, round, clip(2), sub, mul = 9
#   bytes: read g(4) + read ef(4) + write q(1) + write ef(4) = 13
FLOPS_PER_ELEM = 9.0
BYTES_PER_ELEM = 13.0


def _grad_tree(d: int, layers: int, seed: int = 0):
    """Transformer-ish gradient pytree: 12*d^2 + 2*d params per layer."""
    rng = np.random.default_rng(seed)

    def t(*shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)

    return {
        f"layer{i}": {
            "wqkv": t(d, 3 * d), "wo": t(d, d),
            "w1": t(d, 4 * d), "w2": t(4 * d, d),
            "ln1": t(d), "ln2": t(d),
        }
        for i in range(layers)
    }


def _zeros_ef(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _old_pipeline(tree, ef):
    """The replaced path: per-leaf EF quantize map + transpose split +
    host pack of the dequantized f32 leaves."""
    deq, new_ef = compress_grads_int8_ef(tree, ef)
    return pack_grads(deq), new_ef


def _fused_pipeline(tree, ef):
    return pack_grads_fused(tree, ef)


def _best_of(fn, tree, reps: int):
    """Best-of-reps wall time for one pack call (fresh EF each rep so the
    work is identical); returns (seconds, wire_bytes)."""
    best = float("inf")
    nbytes = 0
    for _ in range(reps):
        ef = _zeros_ef(tree)
        jax.block_until_ready(jax.tree.leaves(ef))
        t0 = time.perf_counter()
        data, new_ef = fn(tree, ef)
        jax.block_until_ready(jax.tree.leaves(new_ef))
        best = min(best, time.perf_counter() - t0)
        nbytes = len(data)
    return best, nbytes


def roofline_placement(hw: HW = HW()) -> dict:
    """Analytic placement of the fused kernel on the deployment roofline
    (per-element counts, size-independent)."""
    ai = FLOPS_PER_ELEM / BYTES_PER_ELEM
    ridge = hw.peak_flops / hw.hbm_bw
    compute_s = FLOPS_PER_ELEM / hw.peak_flops  # per element
    memory_s = BYTES_PER_ELEM / hw.hbm_bw
    return {
        "arithmetic_intensity": ai,
        "ridge": ridge,
        "memory_fraction": memory_s / (memory_s + compute_s),
        "bound": "memory" if ai < ridge else "compute",
    }


def run(fast: bool = False) -> dict:
    reps = 3 if fast else 6
    ladder = (CLAIM_POINT,) if fast else LADDER
    rows = []
    data: dict = {"points": {}}
    ratio_at_claim = wire_ratio_at_claim = 0.0
    for d, layers in ladder:
        tree = _grad_tree(d, layers, seed=d)
        # warm both compilation caches outside the timed region
        _old_pipeline(tree, _zeros_ef(tree))
        _fused_pipeline(tree, _zeros_ef(tree))
        t_old, b_old = _best_of(_old_pipeline, tree, reps)
        t_new, b_new = _best_of(_fused_pipeline, tree, reps)
        # correctness spot check while we're here: the wire round-trips
        back = unpack_grads_fused(_fused_pipeline(tree, _zeros_ef(tree))[0], tree)
        assert jax.tree.structure(back) == jax.tree.structure(tree)
        mib = b_old / 2**20
        ratio = t_old / max(t_new, 1e-12)
        wire_ratio = b_old / max(b_new, 1)
        data["points"][f"d{d}x{layers}"] = {
            "grad_mib": mib, "old_s": t_old, "fused_s": t_new,
            "speedup": ratio, "old_wire_bytes": b_old, "fused_wire_bytes": b_new,
            "wire_reduction": wire_ratio,
        }
        if (d, layers) == CLAIM_POINT:
            ratio_at_claim, wire_ratio_at_claim = ratio, wire_ratio
        rows.append({
            "point": f"d={d} L={layers}", "grads": f"{mib:.2f}MiB",
            "old": f"{t_old*1e3:.1f}ms", "fused": f"{t_new*1e3:.1f}ms",
            "speedup": f"{ratio:.2f}x", "wire": f"{wire_ratio:.2f}x smaller",
        })
    roof = roofline_placement()
    data["roofline"] = roof
    claims = [
        Claim("§Perf", "fused device pack >=2x over replaced host pipeline at 4MiB",
              2.0, ratio_at_claim),
        Claim("§Perf", "quantized wire >=3.5x smaller than the f32 wire",
              3.5, wire_ratio_at_claim),
        Claim("§Roofline", "fused pack AI below the ridge (bandwidth-bound)",
              roof["ridge"], roof["arithmetic_intensity"], direction="<="),
        Claim("§Roofline", "memory term >=90% of modeled fused-kernel time",
              0.9, roof["memory_fraction"]),
    ]
    print(table(rows, ["point", "grads", "old", "fused", "speedup", "wire"],
                "Grad-sync pack: replaced pipeline vs fused device kernel"))
    print(f"roofline: AI={roof['arithmetic_intensity']:.2f} flop/B, "
          f"ridge={roof['ridge']:.0f}, {roof['bound']}-bound "
          f"(memory term {roof['memory_fraction']*100:.1f}% of modeled time)")
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {**data, "claims": [c.row() for c in claims]}
    save_result("grad_sync_bench", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
