"""Paper Fig 9 (§5.3): device replication 1→32, lock-free vs coarse try lock.

More devices raise the peak message rate; removing the coarse lock reaches
the peak with fewer devices (NIC resource/memory savings).
"""
from __future__ import annotations

import sys

from repro.amtsim.workloads import flood, octotiger

from .common import Claim, save_result, table

DEVICES = (1, 2, 4, 8, 16, 32)


def run(fast: bool = False) -> dict:
    devices = (1, 2, 4, 8) if fast else DEVICES
    rows = []
    data: dict = {"lockless": {}, "trylock": {}}
    for n in devices:
        for fam, vname in (("lockless", f"lci_d{n}"), ("trylock", f"lci_try_d{n}")):
            r = flood(vname, msg_size=8, nthreads=64, nmsgs=4000).rate
            data[fam][n] = r
        rows.append({"devices": n,
                     "lockless": f"{data['lockless'][n]/1e6:.2f}M/s",
                     "trylock": f"{data['trylock'][n]/1e6:.2f}M/s"})
    app1 = octotiger("lci_d1", n_nodes=8, workers=8, total_subgrids=512, timesteps=3).elapsed
    app4 = octotiger("lci_d4", n_nodes=8, workers=8, total_subgrids=512, timesteps=3).elapsed
    dmax = devices[-1]
    claims = [
        Claim("Fig9", "devices scale lockless message rate (≥3x @ max devices)",
              3.0, data["lockless"][dmax] / data["lockless"][1]),
        Claim("Fig9", "lock removal reaches peak with fewer devices",
              1.0, data["lockless"][2] / data["trylock"][2]),
        Claim("Fig9", "microbenchmark gains do not translate to the app (≤15%)",
              0.85, min(app1 / app4, app4 / app1)),
    ]
    print(table(rows, ["devices", "lockless", "trylock"], "Fig 9 device scaling"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"rates": {k: {str(n): r for n, r in v.items()} for k, v in data.items()},
               "octotiger": {"d1": app1, "d4": app4},
               "claims": [c.row() for c in claims]}
    save_result("factor_devices", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
