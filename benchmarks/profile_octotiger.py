"""Paper Fig 1: Octo-Tiger communication profile — message timeline + size
distribution (frequent small messages, occasional large, no phases)."""
from __future__ import annotations

import sys

import numpy as np

from repro.amtsim.costs import EXPANSE, DEFAULT_MECHANISMS
from repro.amtsim.parcelport_sim import SimWorld, sim_config_for_variant, _Message
from repro.amtsim.workloads import octotiger

from .common import save_result, table


def run(fast: bool = False) -> dict:
    # instrument the injection path to capture (time, size)
    events = []
    orig_inject = SimWorld._inject

    def spy(self, dev, msg):
        events.append((self.env.now, msg.size))
        return orig_inject(self, dev, msg)

    SimWorld._inject = spy
    try:
        octotiger("lci", n_nodes=8, workers=8, total_subgrids=512, timesteps=4)
    finally:
        SimWorld._inject = orig_inject
    times = np.array([t for t, _ in events])
    sizes = np.array([s for _, s in events])
    # (a) messages over time: rate per 10% epoch — no quiet phases
    hist, _ = np.histogram(times, bins=10)
    # (b) size distribution: dominated by small messages
    small_frac = float((sizes <= 4096).mean())
    rows = [
        {"metric": "total messages", "value": len(events)},
        {"metric": "small (≤4 KiB) fraction", "value": f"{small_frac:.2%}"},
        {"metric": "p50 size", "value": int(np.percentile(sizes, 50))},
        {"metric": "p99 size", "value": int(np.percentile(sizes, 99))},
        {"metric": "min epoch msg count", "value": int(hist.min())},
        {"metric": "max epoch msg count", "value": int(hist.max())},
    ]
    print(table(rows, ["metric", "value"], "Fig 1 Octo-Tiger communication profile"))
    always_on = bool(hist.min() > 0.15 * hist.max())
    print(f"claims: small-message dominated={small_frac > 0.8}, no-phases={always_on}")
    payload = {
        "n_messages": len(events),
        "small_fraction": small_frac,
        "epoch_hist": hist.tolist(),
        "claims": [
            {"figure": "Fig1", "claim": "small-message dominated", "paper": 0.8,
             "achieved": round(small_frac, 3), "status": "REPRODUCED" if small_frac > 0.8 else "PARTIAL"},
            {"figure": "Fig1", "claim": "communication has no phases", "paper": 1.0,
             "achieved": float(always_on), "status": "REPRODUCED" if always_on else "PARTIAL"},
        ],
    }
    save_result("profile_octotiger", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
