"""Run every benchmark (one per paper table/figure + the roofline report).

``python -m benchmarks.run [--fast] [--only name1,name2] [--smoke]``

``--smoke`` runs a tiny deterministic protocol-regression gate instead of
the full suite: every parcelport variant must deliver a mixed-size payload
set and quiesce (bounded drain — a deadlock or lost parcel fails the run),
the bounded-injection fabric must exercise backpressure and still deliver,
the eager path must use strictly fewer fabric messages than rendezvous for
sub-threshold parcels, a small DES flood must complete on the main variants
(including ``lci_agg_eager``) with ZERO backpressure under the unbounded
model, a small-queue DES config must report nonzero
``backpressure_events`` while still delivering everything with the send
ring never exceeding its depth, the explicit and implicit progress
policies of the ONE shared ProgressEngine must deliver the same payload
set on the functional core (delivery parity), and the tiny
``progress_contention`` ladder (policy × worker count, §5.3) must
REPRODUCE every claim, every serving-fleet variant must emit token
streams identical to the single-host reference, and the elastic-capacity
path (ISSUE 8) must survive a mid-decode worker leave with a
checkpointed KV handoff — bit-identical tokens, zero drops — while the
reap-latency telemetry (functional engine + DES controller) lands in the
smoke JSON, and the fused grad-pack kernel (ISSUE 9) must emit wire
bytes bit-identical to the host reference in both CI lowerings while
the staged ``'jax'`` hand-off batches a whole drain into one device
transfer.  Results land in ``experiments/bench/smoke.json``
(the CI artifact) and the exit code is non-zero on any failure.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    factor_asynchrony,
    factor_concurrency,
    factor_devices,
    factor_multithreading,
    grad_sync_bench,
    latency,
    message_rate,
    octotiger_scaling,
    profile_octotiger,
    roofline_report,
    slingshot,
)
from .common import save_result

BENCHMARKS = {
    "profile_octotiger": profile_octotiger.run,  # Fig 1
    "message_rate": message_rate.run,  # Fig 3a
    "latency": latency.run,  # Fig 3b
    "octotiger_scaling": octotiger_scaling.run,  # Fig 4
    "slingshot": slingshot.run,  # Fig 5
    "factor_asynchrony": factor_asynchrony.run,  # Fig 6
    "factor_concurrency": factor_concurrency.run,  # Fig 7
    "factor_multithreading": factor_multithreading.run,  # Fig 8
    "factor_devices": factor_devices.run,  # Fig 9
    "roofline_report": roofline_report.run,  # framework §Roofline
    "grad_sync_bench": grad_sync_bench.run,  # §Perf device data plane
}

SMOKE_SEED = 0  # deterministic: the workloads take explicit seeds, no RNG here
SMOKE_PAYLOAD_SIZES = (8, 600, 3_000, 12_000, 40_000)
SMOKE_DES_VARIANTS = ("lci", "lci_eager_64k", "lci_noeager", "lci_agg_eager", "mpi", "mpi_a")


def _smoke_core_variant(name: str, fabric_kwargs=None) -> dict:
    """Deliver mixed-size parcels on one variant; bounded drain raises on
    deadlock/quiesce failure, which the caller records as a regression.
    Stats come from whichever transport carried the bytes (the fabric, or
    the collective group for the ``collective*`` variants)."""
    from repro.core.harness import deliver_payloads, transport_stats

    payloads = [bytes([s % 251]) * s for s in SMOKE_PAYLOAD_SIZES]
    world, got = deliver_payloads(name, payloads, fabric_kwargs=fabric_kwargs, max_rounds=50_000)
    delivered = sorted(len(a[0]) for a in got)
    world.close()  # join any dedicated progress threads (lci_prg{n})
    if delivered != sorted(len(p) for p in payloads):
        raise RuntimeError(f"{name}: delivered {delivered}, expected {sorted(SMOKE_PAYLOAD_SIZES)}")
    st = transport_stats(world)
    return {
        "messages": st.messages,
        "eager_msgs": st.eager_msgs,
        "rendezvous_msgs": st.rendezvous_msgs,
        "backpressure_events": st.backpressure_events,
    }


def smoke() -> int:
    from repro.amtsim.workloads import flood
    from repro.core.variants import variant_names

    failures: list = []
    results: dict = {"variants": {}, "seed": SMOKE_SEED}
    t0 = time.time()

    # 1. every variant delivers and quiesces
    for name in variant_names():
        try:
            results["variants"][name] = _smoke_core_variant(name)
            print(f"smoke core  {name:16s} ok  ({results['variants'][name]['messages']} msgs)")
        except Exception as exc:  # noqa: BLE001 - each variant judged alone
            traceback.print_exc()
            failures.append(f"core:{name}: {exc}")

    # 2. bounded injection: backpressure must fire AND everything delivers
    try:
        bounded = _smoke_core_variant(
            "lci", fabric_kwargs=dict(send_queue_depth=2, bounce_buffers=2, bounce_buffer_size=65_536)
        )
        results["bounded"] = bounded
        if bounded["backpressure_events"] <= 0:
            raise RuntimeError("bounded fabric produced no backpressure events")
        print(f"smoke bound lci ok  ({bounded['backpressure_events']} backpressure events)")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"bounded: {exc}")

    # 3. protocol selection: eager strictly beats rendezvous on messages
    try:
        e = results["variants"].get("lci_eager") or _smoke_core_variant("lci_eager")
        r = results["variants"].get("lci_noeager") or _smoke_core_variant("lci_noeager")
        if not e["messages"] < r["messages"]:
            raise RuntimeError(f"eager used {e['messages']} msgs, noeager {r['messages']}")
        print(f"smoke proto ok  (eager {e['messages']} < noeager {r['messages']} msgs)")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"protocol: {exc}")

    # 4. DES model quiesces and delivers every message
    results["des"] = {}
    for name in SMOKE_DES_VARIANTS:
        try:
            res = flood(name, msg_size=64, nthreads=4, nmsgs=200, max_seconds=2.0)
            results["des"][name] = {"delivered": res.messages, "rate": res.rate}
            if res.messages != 200:
                raise RuntimeError(f"DES {name} delivered {res.messages}/200")
            if res.backpressure_events != 0:
                raise RuntimeError(f"DES {name}: unbounded model reported backpressure")
            print(f"smoke des   {name:16s} ok  ({res.rate/1e6:.2f}M/s)")
        except Exception as exc:  # noqa: BLE001
            traceback.print_exc()
            failures.append(f"des:{name}: {exc}")

    # 5. DES bounded injection: a small-queue config must exercise
    # backpressure, throttle, and still deliver everything
    try:
        import dataclasses

        from repro.amtsim.parcelport_sim import sim_config_for_variant
        from repro.core.comm.resources import ResourceLimits

        bounded_cfg = dataclasses.replace(
            sim_config_for_variant("lci"),
            name="lci_bounded",
            limits=ResourceLimits(send_queue_depth=2, bounce_buffers=2, bounce_buffer_size=16_384),
        )
        res = flood(bounded_cfg, msg_size=64, nthreads=4, nmsgs=200, max_seconds=2.0)
        results["des_bounded"] = {
            "delivered": res.messages,
            "backpressure_events": res.backpressure_events,
            "send_queue_hw": res.send_queue_hw,
            "retry_queue_hw": res.retry_queue_hw,
        }
        if res.messages != 200:
            raise RuntimeError(f"DES bounded delivered {res.messages}/200")
        if res.backpressure_events <= 0:
            raise RuntimeError("DES bounded config produced no backpressure events")
        if res.send_queue_hw > 2:
            raise RuntimeError(f"DES send ring exceeded its depth ({res.send_queue_hw} > 2)")
        print(f"smoke des   bounded lci      ok  ({res.backpressure_events} backpressure events)")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"des_bounded: {exc}")

    # 6. the shared progress engine: explicit vs implicit policy must make
    # identical delivery decisions on the functional core (parity)
    try:
        from repro.core.lci_parcelport import LCIParcelport
        from repro.core.parcelport import World
        from repro.core.variants import VARIANTS

        payloads = [bytes([s % 251]) * s for s in SMOKE_PAYLOAD_SIZES]
        delivered = {}
        for mode in ("explicit", "implicit"):
            cfg = VARIANTS["lci"].variant(name=f"lci_{mode}", progress_mode=mode)
            world = World(2, lambda loc, fab: LCIParcelport(loc, fab, cfg),
                          devices_per_rank=cfg.ndevices)
            got: list = []
            for loc in world.localities:
                loc.register_action("sink", lambda *a, _g=got: _g.append(a))
            for i, pl in enumerate(payloads):
                world.localities[i % 2].async_action((i + 1) % 2, "sink", pl)
            world.drain(max_rounds=50_000)
            delivered[mode] = sorted(len(a[0]) for a in got)
        results["progress_pair"] = delivered
        if delivered["explicit"] != delivered["implicit"]:
            raise RuntimeError(f"explicit/implicit delivery parity broken: {delivered}")
        if delivered["explicit"] != sorted(SMOKE_PAYLOAD_SIZES):
            raise RuntimeError(f"progress pair lost parcels: {delivered}")
        print("smoke engine explicit==implicit delivery parity ok")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"progress_pair: {exc}")

    # 7. progress-policy ladder (§5.3): the tiny contention study's claims
    # must all REPRODUCE (policy x worker count on the one shared engine)
    try:
        from . import message_rate

        _rows, pc_data, pc_claims = message_rate.progress_contention(smoke=True)
        results["progress_contention"] = {
            "rates": {k: {str(t): r for t, r in v.items()} for k, v in pc_data["rates"].items()},
            "claims": [c.row() for c in pc_claims],
        }
        bad = [c.claim for c in pc_claims if not c.ok]
        if bad:
            raise RuntimeError(f"progress_contention claims not reproduced: {bad}")
        print(f"smoke progress_contention ok  ({len(pc_claims)} claims REPRODUCED)")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"progress_contention: {exc}")

    # 8. the collective parity pair: the JAX-collectives backend must
    # replay the LCI backend's engine decision trace bit for bit on the
    # same two-sided config (same protocol, different transport), match
    # its message count, and a bounded collective hand-off must
    # backpressure AND deliver
    try:
        from repro.core.comm.resources import ResourceLimits
        from repro.core.harness import deliver_payloads, transport_stats
        from repro.core.parcelport import World
        from repro.core.variants import make_parcelport_factory, max_devices

        traces = {}
        for name in ("sendrecv_queue", "collective"):
            world = World(2, make_parcelport_factory(name), devices_per_rank=max_devices(name))
            tr: list = []
            for loc in world.localities:
                loc.parcelport.engine.trace = tr
            got: list = []
            world.localities[1].register_action("sink", lambda *a, _g=got: _g.append(a))
            for s in SMOKE_PAYLOAD_SIZES:
                world.localities[0].async_action(1, "sink", bytes([s % 251]) * s)
                world.drain(max_rounds=50_000)
            if len(got) != len(SMOKE_PAYLOAD_SIZES):
                raise RuntimeError(f"{name}: delivered {len(got)}/{len(SMOKE_PAYLOAD_SIZES)}")
            traces[name] = (tr, transport_stats(world).messages)
        if traces["collective"][0] != traces["sendrecv_queue"][0]:
            raise RuntimeError("collective/lci engine decision traces diverged")
        if traces["collective"][1] != traces["sendrecv_queue"][1]:
            raise RuntimeError(
                f"collective used {traces['collective'][1]} msgs, lci {traces['sendrecv_queue'][1]}"
            )
        bounded_coll = _smoke_core_variant(
            "collective",
            fabric_kwargs=dict(limits=ResourceLimits(send_queue_depth=2, bounce_buffers=2,
                                                     bounce_buffer_size=65_536)),
        )
        if bounded_coll["backpressure_events"] <= 0:
            raise RuntimeError("bounded collective hand-off produced no backpressure")
        results["collective_pair"] = {
            "trace_len": len(traces["collective"][0]),
            "messages": traces["collective"][1],
            "bounded_backpressure_events": bounded_coll["backpressure_events"],
        }
        print(f"smoke collective==lci trace parity ok  ({len(traces['collective'][0])} decisions, "
              f"{bounded_coll['backpressure_events']} bounded backpressure events)")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"collective_pair: {exc}")

    # 9. the serving fleet (ISSUE 7): every registered fleet variant must
    # emit token streams identical to the single-host reference on a tiny
    # trace, with zero dropped requests
    try:
        import jax

        from repro.configs import SMOKES
        from repro.core.variants import fleet_variant_names, make_fleet_config
        from repro.models import init_params
        from repro.serve import Fleet, InferenceServer, ServeConfig

        arch = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
        params = init_params(jax.random.PRNGKey(0), arch)
        trace = [([1, 2, 3], 3), ([4, 5, 6, 7], 4), ([8, 9], 3)]
        single = InferenceServer(arch, params,
                                 ServeConfig(slots=4, context=64, transport="inline"))
        ref_reqs = [single.submit(p, max_new=m) for p, m in trace]
        single.run_until_idle()
        ref = [r.out_tokens for r in ref_reqs]
        results["fleet"] = {}
        for name in fleet_variant_names():
            import dataclasses

            cfg = dataclasses.replace(make_fleet_config(name), slots=4, context=64)
            fleet = Fleet(arch, params, cfg)
            try:
                reqs = [fleet.submit(p, max_new=m) for p, m in trace]
                fleet.run_until_idle()
                out = [r.out_tokens for r in reqs]
                results["fleet"][name] = {
                    "workers": cfg.workers, "eagain": fleet.eagain_events,
                    "completed": fleet.completed,
                }
                if not all(r.done_event.is_set() for r in reqs):
                    raise RuntimeError(f"fleet {name} dropped requests")
                if out != ref:
                    raise RuntimeError(f"fleet {name} diverged from single-host")
            finally:
                fleet.close()
            print(f"smoke fleet {name:16s} ok  (w={cfg.workers}, == single-host)")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"fleet: {exc}")

    # 10. elastic capacity (ISSUE 8): a worker leaves the fleet MID-DECODE
    # with a checkpointed KV handoff — token streams must stay identical to
    # the fixed single-host reference with zero drops; the reap-latency
    # telemetry (engine + DES) lands in the smoke JSON for trend tracking
    try:
        import dataclasses

        import jax

        from repro.amtsim.parcelport_sim import sim_config_for_variant
        from repro.amtsim.workloads import octotiger
        from repro.configs import SMOKES
        from repro.models import init_params
        from repro.serve import Fleet, FleetConfig, InferenceServer, ServeConfig

        arch = SMOKES["tinyllama-1.1b"].variant(dtype="float32")
        params = init_params(jax.random.PRNGKey(0), arch)
        trace = [([1, 2, 3], 4), ([4, 5, 6, 7], 5), ([8, 9], 4), ([3, 1], 5)]
        single = InferenceServer(arch, params,
                                 ServeConfig(slots=4, context=64, transport="inline"))
        ref_reqs = [single.submit(p, max_new=m) for p, m in trace]
        single.run_until_idle()
        ref = [r.out_tokens for r in ref_reqs]
        fleet = Fleet(arch, params,
                      FleetConfig(workers=2, slots=4, context=64,
                                  transport="collective", max_workers=3))
        try:
            reqs = [fleet.submit(p, max_new=m) for p, m in trace]
            for _ in range(3):
                fleet.step()  # decode underway before the leave
            fleet.add_worker()
            fleet.leave_worker(0)
            fleet.run_until_idle()
            out = [r.out_tokens for r in reqs]
            engine_reap = fleet.engine.reap_latency_stats() if fleet.engine else {}
            results["elastic_fleet"] = {
                "handoffs": fleet.handoffs, "joins": fleet.joins,
                "leaves": fleet.leaves, "completed": fleet.completed,
                "stale_discards": fleet.membership.stale_discards,
                "engine_reap": engine_reap,
            }
            if not all(r.done_event.is_set() for r in reqs):
                raise RuntimeError("elastic fleet dropped requests across the leave")
            if out != ref:
                raise RuntimeError("elastic fleet diverged from the fixed reference")
            if fleet.handoffs < 1:
                raise RuntimeError("leave_worker moved no slots (handoff path untested)")
        finally:
            fleet.close()
        # DES twin: a compute-heavy mini-storm under the elastic controller
        # must resize, complete every task, and report its reap telemetry
        el_cfg = dataclasses.replace(sim_config_for_variant("lci_prg0"),
                                     name="lci_eprg0_2", elastic_progress=(0, 2))
        r = octotiger(el_cfg, n_nodes=2, workers=6, total_subgrids=32,
                      timesteps=3, task_compute=40e-6)
        results["elastic_des"] = {
            "tasks": r.tasks, "resizes": r.resizes,
            "reap_ewma": r.reap_ewma, "reap_p99": r.reap_p99, "reap_high": r.reap_high,
        }
        if r.tasks != 32 * 3:
            raise RuntimeError(f"elastic DES completed {r.tasks}/96 tasks")
        if r.resizes < 1:
            raise RuntimeError("elastic DES controller never resized under the storm")
        print(f"smoke elastic ok  (fleet: {fleet.handoffs} handoffs, == fixed reference; "
              f"DES: {r.resizes} resizes, p99 reap {r.reap_p99*1e6:.1f}us)")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"elastic: {exc}")

    # 11. device data plane (ISSUE 9): the fused quantize+pack kernel's
    # wire bytes must be BIT-identical to the host reference in both CI
    # lowerings (xla and pallas-interpret), and the staged 'jax' hand-off
    # must batch a whole drain into one device transfer
    try:
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.core.comm.collective import CommChannel
        from repro.kernels.grad_pack import pack_grads_fused
        from repro.train.grad_sync import pack_grads_q8

        rng = np.random.default_rng(SMOKE_SEED)
        tree = {"w": jnp.asarray(rng.standard_normal((70, 30)), jnp.float32),
                "b": jnp.asarray(rng.standard_normal((11,)), jnp.float32)}
        ef = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
        want, _ = pack_grads_q8(tree, ef)
        parity = {}
        for mode in ("xla", "pallas-interpret"):
            got, _ = pack_grads_fused(tree, ef, mode=mode)
            parity[mode] = got == want
            if not parity[mode]:
                raise RuntimeError(f"grad_pack {mode} wire bytes diverged from host reference")
        staged = CommChannel(stage="jax")
        for s in SMOKE_PAYLOAD_SIZES:
            staged.send_request(bytes([s % 251]) * s)
        staged.progress()
        st = staged.group.stats
        if st.staged_batches != 1 or st.staged_bytes != sum(SMOKE_PAYLOAD_SIZES):
            raise RuntimeError(
                f"jax stage did not batch the drain: {st.staged_batches} batches, "
                f"{st.staged_bytes} bytes")
        results["grad_pack"] = {"parity": parity, "wire_bytes": len(want),
                                "staged_batches": st.staged_batches,
                                "staged_bytes": st.staged_bytes}
        print(f"smoke grad_pack ok  (xla+interpret == host, {len(want)}B wire; "
              f"1 staged batch / {st.staged_bytes}B)")
    except Exception as exc:  # noqa: BLE001
        traceback.print_exc()
        failures.append(f"grad_pack: {exc}")

    results["failures"] = failures
    results["elapsed"] = time.time() - t0
    save_result("smoke", results)
    print(f"\nsmoke: {len(failures)} failure(s) in {results['elapsed']:.1f}s: {failures or 'none'}")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true", help="tiny deterministic protocol-regression gate")
    ap.add_argument("--claims-strict", action="store_true",
                    help="non-zero exit if ANY claim is not REPRODUCED (the CI bench-claims gate)")
    args = ap.parse_args()
    if args.smoke:
        return smoke()
    names = list(BENCHMARKS) if not args.only else args.only.split(",")
    failures = []
    n_claims = n_ok = 0
    not_reproduced: list = []
    for name in names:
        print(f"\n{'='*72}\n## {name}\n{'='*72}", flush=True)
        t0 = time.time()
        try:
            payload = BENCHMARKS[name](fast=args.fast)
            for c in (payload or {}).get("claims", []):
                n_claims += 1
                n_ok += c["status"] == "REPRODUCED"
                if c["status"] != "REPRODUCED":
                    not_reproduced.append(f"{name}/{c['figure']}: {c['claim']} "
                                          f"(target {c['paper']}, achieved {c['achieved']})")
        except Exception:  # noqa: BLE001 - keep the suite running
            traceback.print_exc()
            failures.append(name)
        print(f"[{name}: {time.time()-t0:.1f}s]", flush=True)
    print(f"\n{'='*72}\nclaims reproduced: {n_ok}/{n_claims}; benchmark failures: {failures or 'none'}")
    if args.claims_strict and not_reproduced:
        print(f"\nclaims NOT reproduced ({len(not_reproduced)}):")
        for line in not_reproduced:
            print(f"  - {line}")
        return 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
