"""Run every benchmark (one per paper table/figure + the roofline report).

``python -m benchmarks.run [--fast] [--only name1,name2]``
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    factor_asynchrony,
    factor_concurrency,
    factor_devices,
    factor_multithreading,
    latency,
    message_rate,
    octotiger_scaling,
    profile_octotiger,
    roofline_report,
    slingshot,
)

BENCHMARKS = {
    "profile_octotiger": profile_octotiger.run,  # Fig 1
    "message_rate": message_rate.run,  # Fig 3a
    "latency": latency.run,  # Fig 3b
    "octotiger_scaling": octotiger_scaling.run,  # Fig 4
    "slingshot": slingshot.run,  # Fig 5
    "factor_asynchrony": factor_asynchrony.run,  # Fig 6
    "factor_concurrency": factor_concurrency.run,  # Fig 7
    "factor_multithreading": factor_multithreading.run,  # Fig 8
    "factor_devices": factor_devices.run,  # Fig 9
    "roofline_report": roofline_report.run,  # framework §Roofline
}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = list(BENCHMARKS) if not args.only else args.only.split(",")
    failures = []
    n_claims = n_ok = 0
    for name in names:
        print(f"\n{'='*72}\n## {name}\n{'='*72}", flush=True)
        t0 = time.time()
        try:
            payload = BENCHMARKS[name](fast=args.fast)
            for c in (payload or {}).get("claims", []):
                n_claims += 1
                n_ok += c["status"] == "REPRODUCED"
        except Exception:  # noqa: BLE001 - keep the suite running
            traceback.print_exc()
            failures.append(name)
        print(f"[{name}: {time.time()-t0:.1f}s]", flush=True)
    print(f"\n{'='*72}\nclaims reproduced: {n_ok}/{n_claims}; benchmark failures: {failures or 'none'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
