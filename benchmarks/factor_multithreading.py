"""Paper Fig 8 (§5.3): multithreading + progress — the lock ladder.

mpi → block → try → try_progress → block_d2 → lci.
Observation 3: coarse blocking locks dominate; try locks + explicit
frequent progress OR device replication each close the app-level gap;
blocking lock + eager explicit progress is catastrophic.
"""
from __future__ import annotations

import sys

from repro.amtsim.workloads import flood, octotiger

from .common import Claim, save_result, table

VARIANTS = ("mpi", "block", "try", "try_progress", "block_d2", "lci")


def run(fast: bool = False) -> dict:
    rows = []
    data: dict = {}
    for v in VARIANTS:
        rate8 = flood(v, msg_size=8, nthreads=64, nmsgs=4000).rate
        app = octotiger(v, n_nodes=8, workers=8, total_subgrids=512, timesteps=3).elapsed
        data[v] = {"rate_8B": rate8, "octotiger": app}
        rows.append({"variant": v, "rate8": f"{rate8/1e6:.2f}M/s", "octotiger": f"{app*1e3:.2f}ms"})
    # the catastrophic combination: blocking lock + eager explicit progress
    prog = octotiger("progress", n_nodes=8, workers=8, total_subgrids=512, timesteps=3,
                     max_seconds=5.0)
    data["progress"] = {"octotiger": prog.elapsed, "finished_tasks": prog.tasks}
    rows.append({"variant": "progress", "rate8": "-", "octotiger": f"{prog.elapsed*1e3:.2f}ms*"})
    claims = [
        Claim("Fig8", "block ≈ mpi at app level (within 30%)",
              0.7, min(data["block"]["octotiger"] / data["mpi"]["octotiger"],
                       data["mpi"]["octotiger"] / data["block"]["octotiger"])),
        Claim("Fig8", "try_progress recovers app performance vs block",
              1.1, data["block"]["octotiger"] / data["try_progress"]["octotiger"]),
        Claim("Fig8", "device replication (block_d2) recovers app performance",
              1.05, data["block"]["octotiger"] / data["block_d2"]["octotiger"]),
        Claim("Fig8", "try alone < try+explicit progress",
              1.0, data["try"]["octotiger"] / data["try_progress"]["octotiger"]),
        Claim("Fig8", "blocking lock + eager progress is the worst variant",
              1.0, data["progress"]["octotiger"] / data["block"]["octotiger"]),
        Claim("Fig8", "lci microbenchmark rate far above every locked variant",
              2.0, data["lci"]["rate_8B"] / data["block_d2"]["rate_8B"]),
    ]
    print(table(rows, ["variant", "rate8", "octotiger"], "Fig 8 multithreading+progress"))
    print(table([c.row() for c in claims], ["figure", "claim", "paper", "achieved", "status"]))
    payload = {"data": {k: {kk: float(vv) for kk, vv in v.items()} for k, v in data.items()},
               "claims": [c.row() for c in claims]}
    save_result("factor_multithreading", payload)
    return payload


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
